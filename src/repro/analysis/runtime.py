"""Runtime guards: compilations, host transfers, sharding signatures.

The static passes prove the *code* cannot leak tracers; this module
proves the *runtime* holds the serving-path invariants across a region:

* :func:`compile_guard` — zero XLA compilations on a warm stream, via
  ``jax.monitoring`` duration events
  (``/jax/core/compile/backend_compile_duration`` fires exactly once per
  XLA compile, including jit cache misses and Pallas kernel builds).
* :func:`transfer_guard` — zero *implicit* host<->device transfers, via
  ``jax.transfer_guard``.  Implicit transfers are how an un-``_host``-ed
  numpy array sneaks into a jitted program (and, under a mesh, how a
  second sharding signature is born); explicit ``jax.device_put`` /
  ``np.asarray(device_array)`` crossings stay allowed.
* :func:`sharding_guard` — each cached jit program of an
  :class:`~repro.core.spec_decode.SDEngine` sees exactly ONE input
  sharding signature per abstract shape across the region (the PR 9
  one-sharding-signature-per-program rule; a second signature is a
  silent retrace plus a resharding transfer on every call).

All three share the contract::

    with compile_guard() as guard:
        run_more_rounds(...)          # same shapes as warmup
    assert guard.count == 0

``jax.monitoring`` has no listener-removal API, so one module-level
listener feeds a global counter and each guard snapshots it on
enter/exit; guards nest safely.  On backends whose jax build does not
emit compile events, :func:`compilation_events_available` returns False —
the ``compile_guard`` pytest marker (tests/conftest.py) skips those tests
instead of letting vacuous ``count == 0`` assertions pass.
"""
from __future__ import annotations

import contextlib
import functools
import os
import re
import sys
import tempfile
import threading
from typing import Dict, Iterator, List, Optional, Tuple

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_lock = threading.Lock()
_compile_count = 0
_listener_installed = False
_events_available: Optional[bool] = None


def _on_event_duration(event: str, duration: float, **kwargs) -> None:
    global _compile_count
    if event == _COMPILE_EVENT:
        with _lock:
            _compile_count += 1


def _install_listener() -> None:
    global _listener_installed
    if _listener_installed:
        return
    from jax import monitoring
    monitoring.register_event_duration_secs_listener(_on_event_duration)
    _listener_installed = True


def compile_count() -> int:
    """Total XLA compilations observed since the listener was installed."""
    with _lock:
        return _compile_count


class CompileGuard:
    """Handle yielded by :func:`compile_guard`.

    ``count`` is live while the region runs and frozen at exit, so it can
    be inspected both inside and after the ``with`` block.
    """

    def __init__(self) -> None:
        self._start = 0
        self._frozen: Optional[int] = None

    @property
    def count(self) -> int:
        if self._frozen is not None:
            return self._frozen
        return compile_count() - self._start


@contextlib.contextmanager
def compile_guard() -> Iterator[CompileGuard]:
    """Count XLA compilations inside the ``with`` region.

    Installs the module-level monitoring listener on first use (never
    removed — jax.monitoring has no unregister API) and snapshots the
    global counter around the region.  Nesting is fine: each guard owns
    its own snapshot.
    """
    _install_listener()
    guard = CompileGuard()
    guard._start = compile_count()
    try:
        yield guard
    finally:
        guard._frozen = compile_count() - guard._start


def compilation_events_available() -> bool:
    """True when this jax build emits per-compile monitoring events.

    Probes by jitting a fresh (never-cached) function and checking the
    counter moved.  Result is cached; the probe costs one tiny compile.
    """
    global _events_available
    if _events_available is not None:
        return _events_available
    try:
        import jax
        import jax.numpy as jnp
        _install_listener()
        before = compile_count()
        # a fresh closure constant => guaranteed cache miss
        probe = jax.jit(lambda x: x * jnp.float32(1.2345) + 6789.0)
        probe(jnp.zeros((3,), jnp.float32)).block_until_ready()
        _events_available = compile_count() > before
    except Exception:                            # pragma: no cover
        _events_available = False
    return _events_available


# ---------------------------------------------------------------------------
# transfer guard
# ---------------------------------------------------------------------------

# the C++ guard (xla/python/guard_lib.cc) logs one stderr line per guarded
# transfer; Python logging never sees it, so the counter captures fd 2
_TRANSFER_RE = re.compile(
    r"\] (host-to-device|device-to-host|device-to-device) transfer")


class TransferGuard:
    """Handle yielded by :func:`transfer_guard`.

    ``count`` is the number of *implicit* host<->device transfers observed
    in the region — live while it runs, frozen at exit.  ``lines`` holds
    the raw guard log lines for diagnostics (frozen at exit).
    """

    def __init__(self) -> None:
        self._frozen: Optional[int] = None
        self._fd: Optional[int] = None
        self.lines: List[str] = []

    def _read(self) -> str:
        if self._fd is None:
            return ""
        chunks = []
        off = 0
        while True:
            chunk = os.pread(self._fd, 1 << 20, off)
            if not chunk:
                break
            chunks.append(chunk)
            off += len(chunk)
        return b"".join(chunks).decode("utf-8", "replace")

    @property
    def count(self) -> int:
        if self._frozen is not None:
            return self._frozen
        sys.stderr.flush()
        return len(_TRANSFER_RE.findall(self._read()))


@contextlib.contextmanager
def transfer_guard(level: str = "log") -> Iterator[TransferGuard]:
    """Count implicit host<->device transfers inside the ``with`` region.

    Same contract as :func:`compile_guard`::

        with transfer_guard() as guard:
            scheduler.run_stream(...)      # warm stream
        assert guard.count == 0            # every crossing was explicit

    Under ``level="log"`` (default) jax's transfer guard logs each
    implicit transfer to the C-level stderr; the region redirects fd 2 to
    a scratch file, counts matching lines, and replays any non-transfer
    stderr output on exit, so surrounding pytest/fd capture still sees
    it.  ``level="disallow"`` instead RAISES at the offending call — the
    debugging mode: the traceback points at the exact crossing.

    Explicit transfers (``jax.device_put``, ``jnp.asarray(np_array)``,
    ``np.asarray(device_array)``) never count; the guard exists to catch
    the implicit ones that break the one-sharding-signature-per-program
    rule (docs/distributed.md).
    """
    import jax

    guard = TransferGuard()
    if level == "disallow":
        with jax.transfer_guard("disallow"):
            yield guard
        guard._frozen = 0
        return
    if level != "log":
        raise ValueError(f"transfer_guard level must be 'log' or "
                         f"'disallow', got {level!r}")
    sys.stderr.flush()
    saved = os.dup(2)
    tmp = tempfile.TemporaryFile(mode="w+b")
    guard._fd = tmp.fileno()
    os.dup2(tmp.fileno(), 2)
    try:
        with jax.transfer_guard("log"):
            yield guard
    finally:
        sys.stderr.flush()
        os.dup2(saved, 2)
        os.close(saved)
        data = guard._read()
        guard._fd = None
        tmp.close()
        guard.lines = [ln for ln in data.splitlines()
                       if _TRANSFER_RE.search(ln)]
        guard._frozen = len(guard.lines)
        other = [ln for ln in data.splitlines(True)
                 if not _TRANSFER_RE.search(ln)]
        if other:
            sys.stderr.write("".join(other))
            sys.stderr.flush()


# ---------------------------------------------------------------------------
# sharding-signature guard
# ---------------------------------------------------------------------------

#: SDEngine's hand-rolled jit caches (core/spec_decode.py): every compiled
#: program the serving path calls lives in one of these dicts.
_SIG_CACHES = ("_round_cache", "_stage_cache", "_admit_cache",
               "_sliced_cache", "_chunk_cache", "_start_cache",
               "_prefix_cache")


def _canon_sharding(x) -> str:
    """Canonical key for an array's placement: the device -> index-slice
    map.  Two shardings spelled differently — ``P()`` vs ``P(None, None)``,
    a ``GSPMDSharding`` vs the ``NamedSharding`` it round-tripped from, a
    size-1 mesh axis in the spec — are the SAME placement iff every device
    holds the same slice, and only materially different placements make
    jax.jit specialize; comparing ``str(sharding)`` would flag spelling."""
    s = x.sharding
    try:
        imap = s.devices_indices_map(tuple(x.shape))
        return str(sorted((getattr(d, "id", -1), str(idx))
                          for d, idx in imap.items()))
    except Exception:  # noqa: BLE001 — unknown sharding type: fall back
        return str(s)


def _arg_signature(args, kwargs):
    """(aval_sig, canon_sharding_sig, display_sig) over the flattened call
    arguments.

    jax.jit keys its executable cache on avals AND shardings; one abstract
    shape arriving with two materially different shardings is a silent
    retrace."""
    import jax

    aval, canon, shard = [], [], []
    for x in jax.tree_util.tree_leaves((args, kwargs)):
        if isinstance(x, jax.Array):
            aval.append((tuple(x.shape), str(x.dtype)))
            canon.append(_canon_sharding(x))
            shard.append(str(x.sharding))
        else:
            aval.append(("host", type(x).__name__))
            canon.append(f"host:{type(x).__name__}")
            shard.append(f"host:{type(x).__name__}")
    return tuple(aval), tuple(canon), tuple(shard)


class ShardingGuard:
    """Handle yielded by :func:`sharding_guard`.

    ``programs`` counts cached jit programs that were actually called in
    the region; ``violations`` lists ``(program, aval_sig, sharding_sigs)``
    for programs that saw more than one input sharding for the same
    abstract shapes; ``ok`` is True when there are none.
    """

    def __init__(self) -> None:
        #: program label -> aval signature -> canonical placement signature
        #: -> first-seen printable sharding signature.  Keyed on the
        #: canonical form (see ``_canon_sharding``) so equivalent
        #: placements spelled differently collapse to one entry.
        self._sigs: Dict[str, Dict[tuple, Dict[tuple, tuple]]] = {}
        self._lock = threading.Lock()

    def _record(self, program: str, args, kwargs) -> None:
        aval, canon, shard = _arg_signature(args, kwargs)
        with self._lock:
            self._sigs.setdefault(program, {}) \
                .setdefault(aval, {}).setdefault(canon, shard)

    @property
    def programs(self) -> int:
        return len(self._sigs)

    @property
    def violations(self) -> List[Tuple[str, tuple, List[tuple]]]:
        out = []
        for program, by_aval in sorted(self._sigs.items()):
            for aval, by_canon in by_aval.items():
                if len(by_canon) > 1:
                    out.append((program, aval, sorted(by_canon.values())))
        return out

    @property
    def ok(self) -> bool:
        return not self.violations

    def render(self) -> str:
        if self.ok:
            return (f"sharding_guard: {self.programs} program(s), "
                    f"one sharding signature each")
        lines = []
        for program, aval, shards in self.violations:
            lines.append(f"{program}: {len(shards)} sharding signatures "
                         f"for avals {aval}:")
            lines.extend(f"  {s}" for s in shards)
        return "\n".join(lines)


def _wrap_program(guard: ShardingGuard, label: str, fn):
    @functools.wraps(fn)
    def recorded(*args, **kwargs):
        guard._record(label, args, kwargs)
        return fn(*args, **kwargs)

    recorded.__wrapped_by_sharding_guard__ = fn
    return recorded


@contextlib.contextmanager
def sharding_guard(*engines) -> Iterator[ShardingGuard]:
    """Assert one input-sharding signature per cached jit program.

    Takes ``SDEngine`` instances (or ``ServingEngine``s, whose live
    sessions are resolved at entry) and wraps every compiled program in
    their jit caches with a recorder::

        with sharding_guard(engine) as guard:
            scheduler.run_stream(...)      # warm stream
        assert guard.ok and guard.programs > 0

    A program that sees the same abstract shapes under two different
    input shardings has silently retraced — jax.jit keys on shardings —
    and every subsequent call pays a resharding transfer.  The guard
    instruments the *warm* caches: programs built inside the region are
    recorded from their second call on (the first call goes through the
    builder's local reference).  Originals are restored on exit.
    """
    guard = ShardingGuard()
    targets = []
    for eng in engines:
        if hasattr(eng, "_sessions"):            # ServingEngine
            targets.extend(eng._sessions.items())
        else:
            targets.append((type(eng).__name__, eng))
    restores = []
    for name, eng in targets:
        for cache_name in _SIG_CACHES:
            cache = getattr(eng, cache_name, None)
            if not isinstance(cache, dict):
                continue
            for key, value in list(cache.items()):
                label = f"{name}.{cache_name}[{key!r}]"
                if callable(value):
                    restores.append((cache, key, value))
                    cache[key] = _wrap_program(guard, label, value)
                elif isinstance(value, tuple):
                    restores.append((cache, key, value))
                    cache[key] = tuple(
                        _wrap_program(guard, f"{label}[{i}]", v)
                        if callable(v) else v
                        for i, v in enumerate(value))
    try:
        yield guard
    finally:
        for cache, key, value in restores:
            cache[key] = value

"""Runtime retrace guard: count XLA compilations across a code region.

The static passes prove the *code* cannot leak tracers; this module proves
the *runtime* does not recompile.  ``compile_guard()`` counts backend
compilations via ``jax.monitoring`` duration events
(``/jax/core/compile/backend_compile_duration`` fires exactly once per
XLA compile, including jit cache misses and Pallas kernel builds), so
tier-1 tests can assert zero recompiles across steady-state
ContinuousScheduler rounds::

    with compile_guard() as guard:
        run_more_rounds(...)          # same shapes as warmup
    assert guard.count == 0

``jax.monitoring`` has no listener-removal API, so one module-level
listener feeds a global counter and each guard snapshots it on
enter/exit; guards nest safely.  On backends whose jax build does not
emit compile events, :func:`compilation_events_available` returns False —
the ``compile_guard`` pytest marker (tests/conftest.py) skips those tests
instead of letting vacuous ``count == 0`` assertions pass.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator, Optional

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_lock = threading.Lock()
_compile_count = 0
_listener_installed = False
_events_available: Optional[bool] = None


def _on_event_duration(event: str, duration: float, **kwargs) -> None:
    global _compile_count
    if event == _COMPILE_EVENT:
        with _lock:
            _compile_count += 1


def _install_listener() -> None:
    global _listener_installed
    if _listener_installed:
        return
    from jax import monitoring
    monitoring.register_event_duration_secs_listener(_on_event_duration)
    _listener_installed = True


def compile_count() -> int:
    """Total XLA compilations observed since the listener was installed."""
    with _lock:
        return _compile_count


class CompileGuard:
    """Handle yielded by :func:`compile_guard`.

    ``count`` is live while the region runs and frozen at exit, so it can
    be inspected both inside and after the ``with`` block.
    """

    def __init__(self) -> None:
        self._start = 0
        self._frozen: Optional[int] = None

    @property
    def count(self) -> int:
        if self._frozen is not None:
            return self._frozen
        return compile_count() - self._start


@contextlib.contextmanager
def compile_guard() -> Iterator[CompileGuard]:
    """Count XLA compilations inside the ``with`` region.

    Installs the module-level monitoring listener on first use (never
    removed — jax.monitoring has no unregister API) and snapshots the
    global counter around the region.  Nesting is fine: each guard owns
    its own snapshot.
    """
    _install_listener()
    guard = CompileGuard()
    guard._start = compile_count()
    try:
        yield guard
    finally:
        guard._frozen = compile_count() - guard._start


def compilation_events_available() -> bool:
    """True when this jax build emits per-compile monitoring events.

    Probes by jitting a fresh (never-cached) function and checking the
    counter moved.  Result is cached; the probe costs one tiny compile.
    """
    global _events_available
    if _events_available is not None:
        return _events_available
    try:
        import jax
        import jax.numpy as jnp
        _install_listener()
        before = compile_count()
        # a fresh closure constant => guaranteed cache miss
        probe = jax.jit(lambda x: x * jnp.float32(1.2345) + 6789.0)
        probe(jnp.zeros((3,), jnp.float32)).block_until_ready()
        _events_available = compile_count() > before
    except Exception:                            # pragma: no cover
        _events_available = False
    return _events_available

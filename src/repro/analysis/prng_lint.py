"""PRNG-hygiene lint: key dataflow over the serving/training surface.

The engines' determinism contract (docs/serving.md: byte-identical
replay, per-call ``split`` discipline in ``SDEngine.round`` /
``ServingEngine._next_key``) dies silently when a key is reused: two
samples correlate, rejection sampling's acceptance math is wrong, and no
test that only checks shapes will ever notice.  The pass tracks key
values through names, statement by statement (loop bodies are visited
twice so a second iteration's reuse is seen):

========  ===========================================================
 R501     a key is consumed twice with no interleaving ``split`` /
          rebind — includes splitting the same parent twice (the two
          "fresh" keys are identical) and passing one key to two
          sampling calls.
 R502     a ``jax.random.split`` result is discarded (bare expression
          statement, or no derived name is ever read).
 R503     a jitted function closes over a PRNG key instead of taking
          it as an argument — the key is baked into the trace, so
          every cached call replays the same randomness.
 R504     ``fold_in`` with a loop-invariant constant inside a loop —
          every iteration derives the same key (fold_in with the loop
          index is the sanctioned pattern).
========  ===========================================================

Consumption is: a ``jax.random`` sampler taking the key, a ``key=``
keyword on any call, or a positional argument that maps to a key-named
parameter of a project-resolved callee (the interprocedural hop, riding
the same candidate resolution the tracer lint uses).  ``fold_in`` does
NOT consume its parent (per-step derivation from a root key is the
sanctioned loop pattern); ``split`` does (a second split of the same
parent yields identical children).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis._astutil import (FuncInfo, ModuleInfo, Project,
                                     call_keywords, dotted_name)
from repro.analysis.findings import Finding

_SAMPLERS = frozenset({
    "normal", "uniform", "bernoulli", "categorical", "gumbel", "bits",
    "truncated_normal", "randint", "choice", "permutation", "exponential",
    "beta", "gamma", "dirichlet", "laplace", "logistic", "shuffle",
    "rademacher", "cauchy", "multivariate_normal", "poisson", "t",
    "orthogonal", "ball", "loggamma", "rayleigh", "weibull_min",
})
_CREATORS = frozenset({"PRNGKey", "key", "wrap_key_data"})
_JIT_NAMES = ("jax.jit", "jit", "api.jit")
_PARTIAL_NAMES = ("functools.partial", "partial")


def _is_key_param(name: str) -> bool:
    return name == "key" or name in ("rng", "prng", "prng_key") \
        or name.endswith("_key")


def _own_nodes(fi: FuncInfo) -> Iterator[ast.AST]:
    stack: List[ast.AST] = list(fi.body())
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                yield child
                continue
            stack.append(child)


def _flat_names(target: ast.expr) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in target.elts:
            out.extend(_flat_names(e))
        return out
    if isinstance(target, ast.Starred):
        return _flat_names(target.value)
    return []


class _ModuleScope:
    """Module top level presented with the FuncInfo surface the walker
    needs (body/params), so globals get the same key dataflow."""

    def __init__(self, mod: ModuleInfo):
        self.module = mod
        self.node = mod.tree
        self.qualname = "<module>"

    def body(self) -> List[ast.stmt]:
        return [s for s in self.node.body
                if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef))]

    def params(self) -> List[str]:
        return []


class PrngLint:
    def __init__(self, project: Project):
        self.project = project
        self.findings: List[Finding] = []
        self._seen: Set[Tuple[str, int, str]] = set()

    def emit(self, mod: ModuleInfo, line: int, code: str, msg: str) -> None:
        k = (mod.rel, line, code)
        if k not in self._seen:
            self._seen.add(k)
            self.findings.append(Finding(mod.rel, line, code, msg))

    def run(self) -> List[Finding]:
        for mod in self.project.modules.values():
            for fi in mod.functions.values():
                _FuncWalker(self, mod, fi).run()
            _FuncWalker(self, mod, _ModuleScope(mod)).run()
            self._check_jit_captures(mod)
        self.findings.sort(key=lambda f: (f.path, f.line, f.code))
        return self.findings

    # -------------------------------------------------------- random calls
    def random_tail(self, call: ast.Call, mod: ModuleInfo) -> Optional[str]:
        """'split'/'fold_in'/creator/sampler name when ``call`` is a
        ``jax.random`` call (through dotted access or import alias)."""
        dn = dotted_name(call.func)
        if dn is None:
            return None
        tail = dn.rsplit(".", 1)[-1]
        if tail not in _SAMPLERS and tail not in _CREATORS \
                and tail not in ("split", "fold_in"):
            return None
        if "." in dn:
            prefix = dn.rsplit(".", 1)[0]
            if "random" not in prefix.split("."):
                return None
            if prefix.startswith(("np", "numpy")):
                return None
        else:
            target = mod.imports.get(dn, "")
            if not target.startswith("jax.random"):
                return None
        return tail

    # ----------------------------------------------------------------- R503
    def _jitted_locals(self, mod: ModuleInfo
                       ) -> List[Tuple[FuncInfo, int]]:
        """(jitted function, anchor line) for every jit site whose wrapped
        function is a def in the scanned module."""
        out: List[Tuple[FuncInfo, int]] = []
        for fi in list(mod.functions.values()):
            node = fi.node
            if isinstance(node, ast.Lambda):
                continue
            for dec in node.decorator_list:
                dn = dotted_name(dec) or (
                    dotted_name(dec.func) if isinstance(dec, ast.Call)
                    else None)
                if dn in _JIT_NAMES:
                    out.append((fi, node.lineno))
                elif isinstance(dec, ast.Call) and dn in _PARTIAL_NAMES \
                        and dec.args and dotted_name(dec.args[0]) \
                        in _JIT_NAMES:
                    out.append((fi, node.lineno))
        for fi in list(mod.functions.values()):
            for node in _own_nodes(fi):
                if isinstance(node, ast.Call) \
                        and dotted_name(node.func) in _JIT_NAMES \
                        and node.args and isinstance(node.args[0], ast.Name):
                    for cand in self.project.resolve_name(
                            node.args[0].id, mod, fi):
                        out.append((cand, node.lineno))
        return out

    def _key_names_in_scope(self, scope, mod: ModuleInfo) -> Set[str]:
        """Names that hold keys in ``scope``: key-named params plus locals
        assigned from PRNGKey/split/fold_in."""
        names = {p for p in scope.params() if _is_key_param(p)}
        body_nodes = (_own_nodes(scope) if isinstance(scope, FuncInfo)
                      else ast.walk(mod.tree))
        for node in body_nodes:
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call) \
                    and self.random_tail(node.value, mod) in (
                        "split", "fold_in", "PRNGKey", "key",
                        "wrap_key_data"):
                for t in node.targets:
                    names.update(_flat_names(t))
        return names

    def _check_jit_captures(self, mod: ModuleInfo) -> None:
        module_keys = self._key_names_in_scope(_ModuleScope(mod), mod)
        for fi, line in self._jitted_locals(mod):
            scope_keys = set(module_keys)
            if fi.parent is not None:
                scope_keys |= self._key_names_in_scope(fi.parent, mod)
            own = set(fi.params())
            for node in ast.walk(fi.node):
                if isinstance(node, (ast.Assign, ast.AugAssign)):
                    targets = (node.targets
                               if isinstance(node, ast.Assign)
                               else [node.target])
                    for t in targets:
                        own.update(_flat_names(t))
            captured = sorted(
                n.id for n in ast.walk(fi.node)
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
                and n.id in scope_keys and n.id not in own)
            if captured:
                self.emit(mod, line, "R503",
                          f"jitted {fi.name}() closes over PRNG key(s) "
                          f"{captured}: randomness is baked at trace "
                          f"time — pass the key as an argument")


class _FuncWalker:
    """Statement-ordered key dataflow for one function (or module body)."""

    def __init__(self, lint: PrngLint, mod: ModuleInfo, scope):
        self.lint = lint
        self.mod = mod
        self.scope = scope
        #: tracked key name -> line of the consuming use (present=consumed)
        self.consumed: Dict[str, int] = {}
        self.keys: Set[str] = {p for p in scope.params()
                               if _is_key_param(p)}
        self.loop_depth = 0
        self.loop_vars: List[Set[str]] = []
        self._split_assigns: List[Tuple[List[str], int]] = []
        self._loads: Set[str] = set()

    # ------------------------------------------------------------- driver
    def run(self) -> None:
        for node in ast.walk(self.scope.node
                             if isinstance(self.scope, FuncInfo)
                             else self.scope.module.tree):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                self._loads.add(node.id)
        self.visit_block(self.scope.body())
        for targets, line in self._split_assigns:
            live = [t for t in targets if t != "_" and t in self._loads]
            if not live:
                self.lint.emit(self.mod, line, "R502",
                               f"split result(s) {targets} never used — "
                               f"derived keys discarded")

    def visit_block(self, stmts: List[ast.stmt]) -> bool:
        """Visit statements in order; True when the block terminates
        (return/raise/break/continue), so If-merges can drop the state of
        a branch that never falls through."""
        terminated = False
        for stmt in stmts:
            if not terminated:
                terminated = self.visit_stmt(stmt)
        return terminated

    # ---------------------------------------------------------- statements
    def visit_stmt(self, stmt: ast.stmt) -> bool:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return False
        if isinstance(stmt, ast.Assign):
            self._consume_in(stmt.value, rebinding=set(
                n for t in stmt.targets for n in _flat_names(t)))
            self._bind(stmt.targets, stmt.value, stmt.lineno)
            return False
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._consume_in(stmt.value,
                             rebinding=set(_flat_names(stmt.target)))
            self._bind([stmt.target], stmt.value, stmt.lineno)
            return False
        if isinstance(stmt, ast.AugAssign):
            self._consume_in(stmt.value)
            for n in _flat_names(stmt.target):
                self.keys.discard(n)
                self.consumed.pop(n, None)
            return False
        if isinstance(stmt, ast.Expr):
            if isinstance(stmt.value, ast.Call) \
                    and self.lint.random_tail(stmt.value, self.mod) \
                    == "split":
                self.lint.emit(self.mod, stmt.lineno, "R502",
                               "bare jax.random.split(...): the derived "
                               "keys are discarded")
            self._consume_in(stmt.value)
            return False
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._consume_in(stmt.iter)
            targets = set(_flat_names(stmt.target))
            for n in targets:
                self.keys.discard(n)
                self.consumed.pop(n, None)
            self._visit_loop(stmt.body, targets)
            self.visit_block(stmt.orelse)
            return False
        if isinstance(stmt, ast.While):
            self._consume_in(stmt.test)
            self._visit_loop(stmt.body, set())
            self.visit_block(stmt.orelse)
            return False
        if isinstance(stmt, ast.If):
            self._consume_in(stmt.test)
            saved = dict(self.consumed)
            then_term = self.visit_block(stmt.body)
            after_then = self.consumed
            self.consumed = dict(saved)
            else_term = self.visit_block(stmt.orelse)
            # a branch that never falls through contributes no state
            if then_term and not else_term:
                pass                              # keep the else state
            elif else_term and not then_term:
                self.consumed = after_then
            elif not then_term and not else_term:
                for name, line in after_then.items():
                    self.consumed.setdefault(name, line)
            return then_term and else_term
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._consume_in(item.context_expr)
            return self.visit_block(stmt.body)
        if isinstance(stmt, ast.Try):
            self.visit_block(stmt.body)
            for h in stmt.handlers:
                self.visit_block(h.body)
            self.visit_block(stmt.orelse)
            self.visit_block(stmt.finalbody)
            return False
        if isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self._consume_in(stmt.value)
            return True
        if isinstance(stmt, ast.Raise):
            if stmt.exc is not None:
                self._consume_in(stmt.exc)
            return True
        if isinstance(stmt, (ast.Break, ast.Continue)):
            return True
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._consume_in(child)
        return False

    def _visit_loop(self, body: List[ast.stmt],
                    targets: Set[str]) -> None:
        self.loop_depth += 1
        self.loop_vars.append(targets)
        # two passes: the second sees pass-one consumption, so a key that
        # is used-but-not-rederived each iteration trips R501
        self.visit_block(body)
        self.visit_block(body)
        self.loop_vars.pop()
        self.loop_depth -= 1

    def _bind(self, targets: List[ast.expr], value: ast.expr,
              line: int) -> None:
        names = [n for t in targets for n in _flat_names(t)]
        tail = (self.lint.random_tail(value, self.mod)
                if isinstance(value, ast.Call) else None)
        if tail in _CREATORS or tail in ("split", "fold_in"):
            for n in names:
                self.keys.add(n)
                self.consumed.pop(n, None)
            if tail == "split":
                self._split_assigns.append((names, line))
            return
        for n in names:
            self.keys.discard(n)
            self.consumed.pop(n, None)

    # --------------------------------------------------------- consumption
    def _consume_in(self, expr: ast.expr,
                    rebinding: Optional[Set[str]] = None) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                self._consume_call(node, rebinding or set())

    def _consume_call(self, call: ast.Call, rebinding: Set[str]) -> None:
        tail = self.lint.random_tail(call, self.mod)
        kws = call_keywords(call)
        if tail == "split":
            if call.args and isinstance(call.args[0], ast.Name):
                parent = call.args[0].id
                # `key, sub = split(key)` rebinds the parent: sanctioned
                if parent not in rebinding:
                    self._consume(parent, call.lineno,
                                  "split of an already-used key yields "
                                  "the same children")
                elif parent in self.keys:
                    self.consumed.pop(parent, None)
            return
        if tail == "fold_in":
            if self.loop_depth > 0 and len(call.args) > 1 \
                    and isinstance(call.args[1], ast.Constant):
                self.lint.emit(self.mod, call.lineno, "R504",
                               f"fold_in with constant "
                               f"{call.args[1].value!r} inside a loop: "
                               f"every iteration derives the same key — "
                               f"fold in the loop index")
            return
        if tail in _SAMPLERS:
            key_expr = kws.get("key")
            if key_expr is None and call.args:
                key_expr = call.args[0]
            if isinstance(key_expr, ast.Name):
                self._consume(key_expr.id, call.lineno,
                              f"second use in jax.random.{tail}")
            return
        if tail in _CREATORS:
            return
        # non-random call: key= keyword, then positional->key-param mapping
        kw_key = kws.get("key")
        if isinstance(kw_key, ast.Name):
            self._consume(kw_key.id, call.lineno, "second use as key=")
        for cand in self._callees(call):
            pos = cand.positional_params()
            for i, arg in enumerate(call.args):
                if i < len(pos) and _is_key_param(pos[i]) \
                        and isinstance(arg, ast.Name):
                    self._consume(arg.id, call.lineno,
                                  f"second use as {cand.name}() key "
                                  f"argument")

    def _callees(self, call: ast.Call) -> List[FuncInfo]:
        scope = self.scope if isinstance(self.scope, FuncInfo) else None
        if isinstance(call.func, ast.Name):
            cands = self.lint.project.resolve_name(
                call.func.id, self.mod, scope)
        elif isinstance(call.func, ast.Attribute):
            cands = self.lint.project.resolve_attr_call(
                call.func.value, call.func.attr, self.mod)
        else:
            return []
        return cands[:4]

    def _consume(self, name: str, line: int, why: str) -> None:
        if name not in self.keys:
            return
        first = self.consumed.get(name)
        if first is not None:
            # no line numbers in the message: fingerprints must survive
            # unrelated edits shifting the first-use line (ratchet contract)
            self.lint.emit(self.mod, line, "R501",
                           f"key {name!r} consumed earlier and reused: "
                           f"{why} — split first")
        else:
            self.consumed[name] = line


def run(project: Project) -> List[Finding]:
    """Entry point: R5xx findings over the project."""
    return PrngLint(project).run()

"""Entry-point registry: where traced-ness starts, and what is known static.

The tracer lint discovers most entry points syntactically (``jax.jit``
call/decorator sites, ``pl.pallas_call`` kernels, callbacks handed to
``jax.lax`` control flow).  The registry supplements that discovery with
*annotations* the source cannot express:

* ``KNOWN_ENTRY_POINTS`` — hot-path functions that must be analyzed even
  when no jit site in the scanned roots reaches them syntactically (e.g. a
  proposer implementation only ever invoked through the ``Proposer``
  protocol).  Each names its statically-passed params; everything else is
  seeded traced.
* ``ALWAYS_STATIC_PARAMS`` — parameter names that are Python-static by
  repo-wide convention whenever traced-ness is *inferred* (``self``,
  ``cfg`` …).  Call-site flow still wins where a call site is visible.
* ``STATIC_RESULT_ATTRS`` / ``STATIC_RESULT_CALLS`` — attribute reads and
  calls whose result is static even on a traced operand (``x.shape``,
  ``len(x)``), so ``int(x.shape[0])`` never false-positives as a coercion.

Extending the registry (docs/analysis.md): add a :class:`KnownEntry` with
the module-path suffix, the function qualname and its static params —
nothing else; the dataflow takes it from there.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import FrozenSet, Optional, Tuple


@dataclass(frozen=True)
class KnownEntry:
    """One registered analysis root.

    ``module`` is a repo-relative path *suffix* (so the registry is stable
    under repo relocation), ``qualname`` the function's dotted name inside
    the module, ``static`` the params NOT seeded as traced.
    """
    module: str
    qualname: str
    static: Tuple[str, ...] = ()


#: Hot-path roots beyond what jit-site discovery reaches syntactically:
#: the SDEngine round/admission bodies reach these through protocol
#: dispatch (``proposer.*``) or method indirection (``target.*``); listing
#: them keeps the lint exhaustive even if an intermediate call becomes
#: unresolvable.
KNOWN_ENTRY_POINTS: Tuple[KnownEntry, ...] = (
    # target model surface (models/model.py) — reached from every round
    KnownEntry("models/model.py", "Model.prefill",
               static=("self", "collect")),
    KnownEntry("models/model.py", "Model.prefill_with_hidden",
               static=("self", "collect")),
    KnownEntry("models/model.py", "Model.extend",
               static=("self", "collect")),
    KnownEntry("models/model.py", "Model.extend_with_hidden",
               static=("self", "collect")),
    KnownEntry("models/model.py", "Model.extend_with_prefetch",
               static=("self", "collect")),
    KnownEntry("models/model.py", "Model.commit",
               static=("self", "collected")),
    KnownEntry("models/model.py", "merge_cache_rows"),
    KnownEntry("models/model.py", "scatter_cache_rows",
               static=("n_prompt",)),
    # proposer implementations (protocol-dispatched from SDEngine stages)
    KnownEntry("core/proposer.py", "ModelProposer.propose",
               static=("self", "gamma")),
    KnownEntry("core/proposer.py", "ModelProposer.commit",
               static=("self",)),
    KnownEntry("core/proposer.py", "NoneProposer.propose",
               static=("self", "gamma")),
    KnownEntry("core/eagle.py", "EagleProposer.propose",
               static=("self", "gamma")),
    KnownEntry("core/eagle.py", "EagleProposer.commit",
               static=("self",)),
    KnownEntry("core/prefetch.py", "PrefetchProposer.propose",
               static=("self", "gamma")),
    # moe / attention forwards (reached through layer dispatch)
    KnownEntry("models/moe.py", "moe_forward",
               static=("cfg", "dispatch", "return_metrics", "mesh",
                       "mesh_layout")),
    KnownEntry("models/moe.py", "warm_experts", static=("cfg", "mesh")),
    # expert-parallel shard_map dispatch (distributed/collectives.py):
    # moe_ep_forward is the mesh entry, _ragged_ep_shard the per-shard
    # body (everything bound via functools.partial there is static)
    KnownEntry("distributed/collectives.py", "moe_ep_forward",
               static=("cfg", "mesh", "layout", "capacity_factor",
                       "interpret")),
    KnownEntry("distributed/collectives.py", "_ragged_ep_shard",
               static=("cfg", "slots", "activation", "model_axis",
                       "m_shards", "interpret")),
    # shard_map body of the expert-prefetch warm gather (models/moe.py):
    # nested def, reached only through the shard_map site, so jit-site
    # discovery never sees it
    KnownEntry("models/moe.py", "warm_experts._local_gather"),
    KnownEntry("distributed/constraints.py", "constrain",
               static=("kind", "mesh", "layout")),
    KnownEntry("models/attention.py", "attention_forward",
               static=("cfg",)),
    # paged decode/verify attention kernel (reached from gqa_forward's
    # paged extend branch; scale/logit_cap fold into the kernel closure)
    KnownEntry("kernels/decode_attention/ops.py", "paged_decode_attention",
               static=("scale", "logit_cap", "interpret")),
    # numerical sentinel (serving/faults.py) — runs inside the jitted
    # verify stage on the raw logits every round
    KnownEntry("serving/faults.py", "logits_finite"),
    # batched rejection sampling (the REJECT stage) — temperature is a
    # Python float by contract (the greedy branch is a trace-time choice)
    KnownEntry("core/rejection.py", "rejection_sample",
               static=("temperature",)),
    KnownEntry("core/rejection.py", "sample_from",
               static=("temperature",)),
    KnownEntry("core/rejection.py", "probs_from_logits",
               static=("temperature",)),
)

#: Param names treated static when traced-ness must be inferred (registry
#: roots and protocol-dispatched methods).  Where an actual call site is
#: visible, flow from the site overrides this list.
ALWAYS_STATIC_PARAMS: FrozenSet[str] = frozenset({
    "self", "cls", "cfg", "config", "tcfg", "dcfg", "target_cfg",
})

#: Attribute reads that are static even on a traced value.
STATIC_RESULT_ATTRS: FrozenSet[str] = frozenset({
    "shape", "dtype", "ndim", "size", "aval", "sharding",
})

#: Calls whose result is static regardless of traced arguments.
STATIC_RESULT_CALLS: FrozenSet[str] = frozenset({
    "len", "isinstance", "issubclass", "hasattr", "getattr", "type",
    "callable", "id", "repr", "range",
})


#: Axis names the repo's meshes can carry (launch/mesh.py builds
#: ("pod","data","model") sub-meshes; docs/distributed.md).  The sharding
#: lint (S401) resolves collective axis names against the enclosing
#: shard_map's spec literals first and falls back to this set when the
#: mesh expression is a runtime value — so a typo'd axis name is caught
#: even where the mesh is not statically known.
KNOWN_MESH_AXES: FrozenSet[str] = frozenset({"pod", "data", "model"})


@dataclass(frozen=True)
class DonationCandidate:
    """A hot-path buffer the ROADMAP expects to be donated eventually.

    ``module``/``qualname`` locate the function that produces or updates
    the buffer, ``param`` names it, ``note`` carries the tracking context
    surfaced in the D602 message.  The donation lint fires D602 at the
    function's def line unless some jit site in the scanned tree donates
    an argument into it — turning "TODO: donate" comments into findings
    the ratchet tracks.
    """
    module: str
    qualname: str
    param: str
    note: str


#: Buffers with acknowledged donation headroom.  Waive the finding inline
#: (with the reason) while the headroom is accepted; delete the entry when
#: the donation lands.
DONATION_CANDIDATES: Tuple[DonationCandidate, ...] = (
    DonationCandidate(
        "models/moe.py", "warm_experts", "layer_params",
        "ROADMAP: warmed expert buffers stay simulation-only until they "
        "are donated to the gmm dispatch"),
)


def lookup_entry(module_rel: str, qualname: str) -> Optional[KnownEntry]:
    """Find the registry entry for ``qualname`` in the module whose
    repo-relative path ends with the entry's ``module`` suffix."""
    for e in KNOWN_ENTRY_POINTS:
        if module_rel.endswith(e.module) and e.qualname == qualname:
            return e
    return None

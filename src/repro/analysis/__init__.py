"""Repo-native static analysis + runtime guards for the jit/Pallas stack.

Six source-level passes (no imports of the analyzed code, no accelerator
needed) plus three runtime guards:

* :mod:`repro.analysis.tracer_lint` — tracer-safety dataflow (T1xx),
* :mod:`repro.analysis.cache_keys` — jit-cache-key audit (K2xx),
* :mod:`repro.analysis.pallas_lint` — Pallas kernel contracts (P3xx),
* :mod:`repro.analysis.sharding_lint` — shard_map/collective and
  host-boundary contracts (S4xx),
* :mod:`repro.analysis.prng_lint` — PRNG key dataflow (R5xx),
* :mod:`repro.analysis.donation_lint` — buffer donation (D6xx),
* :mod:`repro.analysis.runtime` — ``compile_guard()`` XLA-compile
  counter, ``transfer_guard()`` implicit host<->device transfer counter,
  ``sharding_guard()`` one-sharding-signature-per-program assertion.

Run the analyzer with ``python -m repro.analysis src/repro`` (see
``scripts/lint.sh`` for the CI invocation against the ratchet baseline)
and read ``docs/analysis.md`` for the finding codes, the traced-ness /
key-dataflow / host-boundary models, and how to extend the entry-point
registry.
"""
from __future__ import annotations

import os
from typing import List, Optional, Sequence

from repro.analysis import (cache_keys, donation_lint, pallas_lint,
                            prng_lint, sharding_lint, tracer_lint)
from repro.analysis._astutil import Project
from repro.analysis.findings import (CODES, PASSES, Finding, Report,
                                     apply_waivers, load_baseline,
                                     parse_waivers, pass_of, ratchet,
                                     write_baseline)
from repro.analysis.pallas_lint import _DEFAULT_VMEM_BUDGET
from repro.analysis.runtime import (CompileGuard, ShardingGuard,
                                    TransferGuard,
                                    compilation_events_available,
                                    compile_count, compile_guard,
                                    sharding_guard, transfer_guard)

__all__ = [
    "CODES", "PASSES", "Finding", "Report", "analyze_paths",
    "compile_guard", "CompileGuard", "compile_count",
    "compilation_events_available", "transfer_guard", "TransferGuard",
    "sharding_guard", "ShardingGuard", "pass_of",
    "load_baseline", "ratchet", "write_baseline",
]


def analyze_paths(paths: Sequence[str], repo_root: Optional[str] = None,
                  vmem_budget: int = _DEFAULT_VMEM_BUDGET) -> List[Finding]:
    """Run all static passes over ``paths`` (files or directories) and
    return findings with inline waivers already applied, sorted by
    location.  ``repo_root`` anchors the repo-relative finding paths
    (defaults to the current directory, which is where CI runs)."""
    root = os.path.abspath(repo_root or os.getcwd())
    project = Project(list(paths), root)
    findings: List[Finding] = []
    findings += tracer_lint.run(project)
    findings += cache_keys.run(project)
    findings += pallas_lint.run(project, vmem_budget=vmem_budget)
    findings += sharding_lint.run(project)
    findings += prng_lint.run(project)
    findings += donation_lint.run(project)
    waivers = {mod.rel: parse_waivers(mod.source)
               for mod in project.modules.values()}
    kept = apply_waivers(findings, waivers)
    kept.sort(key=lambda f: (f.path, f.line, f.code))
    return kept

"""Repo-native static analysis + retrace guard for the jit/Pallas stack.

Three source-level passes (no imports of the analyzed code, no
accelerator needed) plus one runtime guard:

* :mod:`repro.analysis.tracer_lint` — tracer-safety dataflow (T1xx),
* :mod:`repro.analysis.cache_keys` — jit-cache-key audit (K2xx),
* :mod:`repro.analysis.pallas_lint` — Pallas kernel contracts (P3xx),
* :mod:`repro.analysis.runtime` — ``compile_guard()`` XLA-compile counter.

Run the analyzer with ``python -m repro.analysis src/repro`` (see
``scripts/lint.sh`` for the CI invocation against the ratchet baseline)
and read ``docs/analysis.md`` for the finding codes, the traced-ness
model, and how to extend the entry-point registry.
"""
from __future__ import annotations

import os
from typing import List, Optional, Sequence

from repro.analysis import cache_keys, pallas_lint, tracer_lint
from repro.analysis._astutil import Project
from repro.analysis.findings import (CODES, Finding, Report, apply_waivers,
                                     load_baseline, parse_waivers, ratchet,
                                     write_baseline)
from repro.analysis.pallas_lint import _DEFAULT_VMEM_BUDGET
from repro.analysis.runtime import (CompileGuard, compilation_events_available,
                                    compile_count, compile_guard)

__all__ = [
    "CODES", "Finding", "Report", "analyze_paths", "compile_guard",
    "CompileGuard", "compile_count", "compilation_events_available",
    "load_baseline", "ratchet", "write_baseline",
]


def analyze_paths(paths: Sequence[str], repo_root: Optional[str] = None,
                  vmem_budget: int = _DEFAULT_VMEM_BUDGET) -> List[Finding]:
    """Run all static passes over ``paths`` (files or directories) and
    return findings with inline waivers already applied, sorted by
    location.  ``repo_root`` anchors the repo-relative finding paths
    (defaults to the current directory, which is where CI runs)."""
    root = os.path.abspath(repo_root or os.getcwd())
    project = Project(list(paths), root)
    findings: List[Finding] = []
    findings += tracer_lint.run(project)
    findings += cache_keys.run(project)
    findings += pallas_lint.run(project, vmem_budget=vmem_budget)
    waivers = {mod.rel: parse_waivers(mod.source)
               for mod in project.modules.values()}
    kept = apply_waivers(findings, waivers)
    kept.sort(key=lambda f: (f.path, f.line, f.code))
    return kept

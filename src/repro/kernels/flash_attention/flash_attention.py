"""Causal (optionally sliding-window) flash attention, Pallas TPU.

Online-softmax tiling: grid (B, Hq, Tq/bq, S/bk), KV innermost.  Running
max / sum / accumulator live in VMEM scratch across the KV dimension,
initialized at k==0 and written out at the last *visited* KV block.  GQA is
handled in the index map — the K/V block index is ``h // group`` — so K/V
are never repeated in memory.

VMEM budget per step (bq=bk=128, d=256, bf16 in / f32 acc):
  q 64 KiB + k 64 KiB + v 64 KiB + acc 128 KiB + m/l 1 KiB  ≈ 0.3 MiB ≪ 16 MiB.
Block shapes are MXU-aligned (128 lanes); the two matmuls per step hit the
systolic array at full tile occupancy.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,       # blocks
    m_ref, l_ref, acc_ref,            # VMEM scratch
    *,
    bq: int, bk: int, nk: int,
    scale: float, causal: bool, window: int, logit_cap: float,
):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    def _step():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if logit_cap > 0:
            s = jnp.tanh(s / logit_cap) * logit_cap
        if causal:
            mask = k_pos <= q_pos
            if window > 0:
                mask &= k_pos > q_pos - window
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    if causal:
        # whole-block visibility test (cheap skip of fully-masked blocks)
        needed = (ik * bk) <= (iq * bq + bq - 1)
        if window > 0:
            needed = jnp.logical_and(needed, (ik * bk + bk - 1) > (iq * bq - window))
        pl.when(needed)(_step)
    else:
        _step()

    @pl.when(ik == nk - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "logit_cap", "bq", "bk", "interpret"),
)
def flash_attention_bhtd(
    q: jnp.ndarray,            # (B, Hq, T, D)
    k: jnp.ndarray,            # (B, Hkv, S, D)
    v: jnp.ndarray,            # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: int = 0,
    scale: float = 0.0,
    logit_cap: float = 0.0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    g = Hq // Hkv
    if scale == 0.0:
        scale = 1.0 / math.sqrt(D)
    bq, bk = min(bq, T), min(bk, S)
    assert T % bq == 0 and S % bk == 0, (T, S, bq, bk)
    nq, nk = T // bq, S // bk
    grid = (B, Hq, nq, nk)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, scale=scale,
        causal=causal, window=window, logit_cap=logit_cap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Public jit wrapper: (B, T, H, D) layout used by the model code."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhtd

INTERPRET = jax.default_backend() != "tpu"


def flash_attention(
    q: jnp.ndarray,            # (B, T, Hq, D)
    k: jnp.ndarray,            # (B, S, Hkv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float = 0.0,
    logit_cap: float = 0.0,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    out = flash_attention_bhtd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, window=window, scale=scale, logit_cap=logit_cap,
        interpret=interpret)
    return out.transpose(0, 2, 1, 3)

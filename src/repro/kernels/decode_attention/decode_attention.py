"""Decode / SD-verify attention Pallas TPU kernel.

The paper's verification step attends T = gamma+1 fresh query tokens against
a long KV cache at per-sequence offsets ``lengths`` — this kernel is that
hot spot.  Compared to prefill flash attention:

  * T is tiny (1..8): one q block covers all queries; the q tile is padded
    to the 8-row TPU sublane minimum.
  * masking is ``k_pos <= length + t`` (per sequence, per query row), not a
    static triangle,
  * grid (B, Hkv, S/bk) — KV innermost, online softmax in VMEM scratch; the
    g = Hq/Hkv query heads of a KV head are folded into the q-tile rows
    (rows = g * T_pad), so GQA costs no extra KV traffic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,                   # scalar prefetch: (B,) lengths
    q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *,
    bk: int, nk: int, t_pad: int, t_real: int, scale: float, logit_cap: float,
):
    b, ik = pl.program_id(0), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    rows = q_ref.shape[2]                                  # g * t_pad
    # query position per row: length + (row % t_pad), capped by t_real
    row_t = jax.lax.broadcasted_iota(jnp.int32, (rows, bk), 0) % t_pad
    q_pos = length + row_t
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (rows, bk), 1)
    valid = (k_pos <= q_pos) & (row_t < t_real)

    def _step():
        q = q_ref[0, 0].astype(jnp.float32)                # (rows, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if logit_cap > 0:
            s = jnp.tanh(s / logit_cap) * logit_cap
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # skip KV blocks entirely beyond the newest query position
    pl.when(ik * bk <= length + t_real - 1)(_step)

    @pl.when(ik == nk - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_decode_kernel(
    len_ref,                   # scalar prefetch: (B,) lengths
    tbl_ref,                   # scalar prefetch: (B * MP,) flattened block table
    q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *,
    ps: int, mp: int, t_pad: int, t_real: int, scale: float, logit_cap: float,
):
    del tbl_ref  # consumed by the K/V index maps, not the kernel body
    b, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    rows = q_ref.shape[2]                                  # g * t_pad
    # query position per row: length + (row % t_pad), capped by t_real
    row_t = jax.lax.broadcasted_iota(jnp.int32, (rows, ps), 0) % t_pad
    q_pos = length + row_t
    # logical KV position of this page's slots; physical placement is
    # resolved by the block-table index map, the mask only sees logical
    k_pos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (rows, ps), 1)
    valid = (k_pos <= q_pos) & (row_t < t_real)

    def _step():
        q = q_ref[0, 0].astype(jnp.float32)                # (rows, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (ps, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if logit_cap > 0:
            s = jnp.tanh(s / logit_cap) * logit_cap
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (ps, d)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # skip logical pages entirely beyond the newest query position;
    # unallocated table entries point at the trash page but are never
    # reached because their logical position exceeds length + t_real - 1
    pl.when(j * ps <= length + t_real - 1)(_step)

    @pl.when(j == mp - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "logit_cap", "interpret"))
def paged_decode_attention_bhtd(
    q: jnp.ndarray,            # (B, Hq, T, D), T = gamma+1 fresh queries
    k_pages: jnp.ndarray,      # (NP, ps, Hkv, D) physical page pool
    v_pages: jnp.ndarray,
    lengths: jnp.ndarray,      # (B,) committed lengths (queries at length+t)
    table: jnp.ndarray,        # (B, MP) logical page -> physical page
    *,
    scale: float = 0.0,
    logit_cap: float = 0.0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Decode/verify attention reading KV straight from the paged pool.

    Walks the block table inside the Pallas grid: the K/V index maps look
    the physical page id up in the scalar-prefetched flattened ``table``
    (the ``kernels/gmm/ragged.py`` idiom), so no dense ``pool[table]``
    gather is ever materialized in HBM.  Same online-softmax body and
    masking contract as :func:`decode_attention_bhtd`, with the KV axis
    walked one page per grid step instead of one ``bk`` block.
    """
    B, Hq, T, D = q.shape
    NP, ps, Hkv, _ = k_pages.shape
    MP = table.shape[1]
    g = Hq // Hkv
    if scale == 0.0:
        scale = 1.0 / math.sqrt(D)
    t_pad = max(8 // max(g, 1), T)                          # sublane alignment
    rows = g * t_pad
    # fold (g, T) query heads/steps into rows of one tile
    qf = q.reshape(B, Hkv, g, T, D)
    qf = jnp.pad(qf, ((0, 0), (0, 0), (0, 0), (0, t_pad - T), (0, 0)))
    qf = qf.reshape(B, Hkv, rows, D)
    kernel = functools.partial(
        _paged_decode_kernel, ps=ps, mp=MP, t_pad=t_pad, t_real=T,
        scale=scale, logit_cap=logit_cap)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, Hkv, MP),
            in_specs=[
                pl.BlockSpec((1, 1, rows, D),
                             lambda b, h, j, lens, tbl: (b, h, 0, 0)),
                pl.BlockSpec((1, ps, 1, D),
                             lambda b, h, j, lens, tbl: (tbl[b * MP + j], 0, h, 0)),
                pl.BlockSpec((1, ps, 1, D),
                             lambda b, h, j, lens, tbl: (tbl[b * MP + j], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rows, D),
                                   lambda b, h, j, lens, tbl: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rows, 1), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
                pltpu.VMEM((rows, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rows, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), table.reshape(-1).astype(jnp.int32),
      qf, k_pages, v_pages)
    out = out.reshape(B, Hkv, g, t_pad, D)[:, :, :, :T]
    return out.reshape(B, Hq, T, D)


@functools.partial(
    jax.jit, static_argnames=("scale", "logit_cap", "bk", "interpret"))
def decode_attention_bhtd(
    q: jnp.ndarray,            # (B, Hq, T, D), T = gamma+1 fresh queries
    k: jnp.ndarray,            # (B, Hkv, S, D) cache INCLUDING fresh writes
    v: jnp.ndarray,
    lengths: jnp.ndarray,      # (B,) committed lengths (queries at length+t)
    *,
    scale: float = 0.0,
    logit_cap: float = 0.0,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    g = Hq // Hkv
    if scale == 0.0:
        scale = 1.0 / math.sqrt(D)
    t_pad = max(8 // max(g, 1), T)                          # sublane alignment
    rows = g * t_pad
    # fold (g, T) query heads/steps into rows of one tile
    qf = q.reshape(B, Hkv, g, T, D)
    qf = jnp.pad(qf, ((0, 0), (0, 0), (0, 0), (0, t_pad - T), (0, 0)))
    qf = qf.reshape(B, Hkv, rows, D)
    bk = min(bk, S)
    pad = (-S) % bk
    if pad:  # pad the KV length; padded slots sit beyond every query position
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        S = S + pad
    nk = S // bk
    kernel = functools.partial(
        _decode_kernel, bk=bk, nk=nk, t_pad=t_pad, t_real=T,
        scale=scale, logit_cap=logit_cap)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, Hkv, nk),
            in_specs=[
                pl.BlockSpec((1, 1, rows, D), lambda b, h, j, lens: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bk, D), lambda b, h, j, lens: (b, h, j, 0)),
                pl.BlockSpec((1, 1, bk, D), lambda b, h, j, lens: (b, h, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rows, D), lambda b, h, j, lens: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rows, 1), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
                pltpu.VMEM((rows, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rows, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qf, k, v)
    out = out.reshape(B, Hkv, g, t_pad, D)[:, :, :, :T]
    return out.reshape(B, Hq, T, D)


def _selfcheck() -> None:
    """Interpret-mode parity: paged kernel vs paged oracle vs dense oracle."""
    import numpy as np

    from repro.kernels.decode_attention.ref import (
        decode_attention_ref, paged_decode_attention_ref)

    rng = np.random.default_rng(0)
    for (B, Hq, Hkv, T, D, ps, MP, cap) in [
        (2, 4, 2, 3, 128, 8, 6, 0.0),
        (3, 4, 4, 1, 128, 16, 4, 30.0),
        (1, 8, 2, 5, 128, 64, 3, 0.0),
    ]:
        NP = B * MP + 1                                     # page 0 = trash
        lengths = rng.integers(0, MP * ps - T, size=(B,)).astype(np.int32)
        # each row owns ceil((length+T)/ps) pages; the rest point at trash
        table = np.zeros((B, MP), np.int32)
        nxt = 1
        for b in range(B):
            for lp in range((int(lengths[b]) + T + ps - 1) // ps):
                table[b, lp] = nxt
                nxt += 1
        k_pages = rng.standard_normal((NP, ps, Hkv, D)).astype(np.float32)
        v_pages = rng.standard_normal((NP, ps, Hkv, D)).astype(np.float32)
        q = rng.standard_normal((B, Hq, T, D)).astype(np.float32)
        got = paged_decode_attention_bhtd(
            q, k_pages, v_pages, lengths, table, logit_cap=cap,
            interpret=True)
        want = paged_decode_attention_ref(
            q, k_pages, v_pages, lengths, table, logit_cap=cap)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
        # paged oracle == dense oracle on the gathered view
        kd = k_pages[table].reshape(B, MP * ps, Hkv, D).transpose(0, 2, 1, 3)
        vd = v_pages[table].reshape(B, MP * ps, Hkv, D).transpose(0, 2, 1, 3)
        dense = decode_attention_ref(q, kd, vd, lengths, logit_cap=cap)
        np.testing.assert_allclose(want, dense, rtol=2e-5, atol=2e-5)
        print(f"paged_decode_attention ps={ps} MP={MP} B={B} "
              f"Hq/Hkv={Hq}/{Hkv} T={T} cap={cap}: OK")


if __name__ == "__main__":
    _selfcheck()

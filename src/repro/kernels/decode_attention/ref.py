"""Pure-jnp oracle for decode/verify attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(
    q: jnp.ndarray,            # (B, Hq, T, D)
    k: jnp.ndarray,            # (B, Hkv, S, D)
    v: jnp.ndarray,
    lengths: jnp.ndarray,      # (B,)
    *,
    scale: float = 0.0,
    logit_cap: float = 0.0,
) -> jnp.ndarray:
    B, Hq, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if scale == 0.0:
        scale = 1.0 / math.sqrt(D)
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if logit_cap > 0:
        s = jnp.tanh(s / logit_cap) * logit_cap
    q_pos = lengths[:, None, None, None] + jnp.arange(T)[None, None, :, None]
    k_pos = jnp.arange(S)[None, None, None, :]
    s = jnp.where(k_pos <= q_pos, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_attention_ref(
    q: jnp.ndarray,            # (B, Hq, T, D)
    k_pages: jnp.ndarray,      # (NP, ps, Hkv, D) physical page pool
    v_pages: jnp.ndarray,
    lengths: jnp.ndarray,      # (B,)
    table: jnp.ndarray,        # (B, MP) logical page -> physical page
    *,
    scale: float = 0.0,
    logit_cap: float = 0.0,
) -> jnp.ndarray:
    """Oracle for the paged kernel: gather ``pool[table]`` into the dense
    (B, Hkv, MP*ps, D) view, then delegate to :func:`decode_attention_ref`.
    Logical positions beyond ``length + T - 1`` are masked there, so trash
    or stale page contents never reach the softmax."""
    B, MP = table.shape
    ps = k_pages.shape[1]

    def view(pool):
        g = jnp.asarray(pool)[jnp.asarray(table)]           # (B, MP, ps, Hkv, D)
        return g.reshape((B, MP * ps) + pool.shape[2:]).transpose(0, 2, 1, 3)

    return decode_attention_ref(
        q, view(k_pages), view(v_pages), lengths,
        scale=scale, logit_cap=logit_cap)

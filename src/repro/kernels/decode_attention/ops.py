"""Public jit wrapper, (B, T, H, D) layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_bhtd, paged_decode_attention_bhtd)

INTERPRET = jax.default_backend() != "tpu"


def decode_attention(
    q: jnp.ndarray,            # (B, T, Hq, D)
    k: jnp.ndarray,            # (B, S, Hkv, D)
    v: jnp.ndarray,
    lengths: jnp.ndarray,      # (B,)
    *,
    scale: float = 0.0,
    logit_cap: float = 0.0,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    """Dense decode/verify attention over a contiguous (B, S) KV cache:
    the online-softmax Pallas kernel behind the non-paged extend path.
    Queries sit at absolute positions ``lengths + t`` (causal)."""
    out = decode_attention_bhtd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        lengths, scale=scale, logit_cap=logit_cap, interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def paged_decode_attention(
    q: jnp.ndarray,            # (B, T, Hq, D)
    k_pages: jnp.ndarray,      # (NP, ps, Hkv, D) physical page pool
    v_pages: jnp.ndarray,
    lengths: jnp.ndarray,      # (B,)
    table: jnp.ndarray,        # (B, MP) logical page -> physical page
    *,
    scale: float = 0.0,
    logit_cap: float = 0.0,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    """Block-table-walking decode/verify attention over the paged KV pool.

    Reads K/V pages directly from the pool via scalar-prefetched page
    indices — no ``pool[table]`` dense gather — and returns (B, T, Hq, D)
    matching :func:`decode_attention` on the gathered view exactly (same
    masking contract; positions past ``length + t`` never contribute).
    """
    out = paged_decode_attention_bhtd(
        q.transpose(0, 2, 1, 3), k_pages, v_pages, lengths, table,
        scale=scale, logit_cap=logit_cap, interpret=interpret)
    return out.transpose(0, 2, 1, 3)

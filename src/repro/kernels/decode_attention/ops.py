"""Public jit wrapper, (B, T, H, D) layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention_bhtd

INTERPRET = jax.default_backend() != "tpu"


def decode_attention(
    q: jnp.ndarray,            # (B, T, Hq, D)
    k: jnp.ndarray,            # (B, S, Hkv, D)
    v: jnp.ndarray,
    lengths: jnp.ndarray,      # (B,)
    *,
    scale: float = 0.0,
    logit_cap: float = 0.0,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    out = decode_attention_bhtd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        lengths, scale=scale, logit_cap=logit_cap, interpret=interpret)
    return out.transpose(0, 2, 1, 3)

"""Jitted public wrappers around the gmm kernel: capacity dispatch → grouped
matmul → weighted combine, i.e. a full MoE FFN built on the kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gmm.gmm import gmm_capacity
from repro.kernels.gmm.ref import combine_ref, dispatch_ref

# Pallas TPU kernels run in interpret mode everywhere but real TPU.
INTERPRET = jax.default_backend() != "tpu"


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def expert_capacity(n_tokens: int, k: int, num_experts: int,
                    capacity_factor: float = 2.0, align: int = 128) -> int:
    """Fixed per-expert bin size; paper §3.2 assumes balanced routing, so a
    2x factor keeps drops negligible (validated in tests)."""
    mean = n_tokens * k / num_experts
    return max(align, _round_up(int(mean * capacity_factor), align))


@functools.partial(jax.jit, static_argnames=("capacity", "activation", "interpret"))
def moe_ffn_gmm(
    x: jnp.ndarray,            # (N, D)
    w_gate: jnp.ndarray,       # (E, D, F)
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,       # (E, F, D)
    weights: jnp.ndarray,      # (N, K) router weights
    indices: jnp.ndarray,      # (N, K) expert ids
    *,
    capacity: int,
    activation: str = "silu",
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    E, D, F = w_gate.shape
    N = x.shape[0]
    bins, slot, kept = dispatch_ref(x, indices, E, capacity)
    # pad C and D/F to MXU-aligned tiles
    C = bins.shape[1]
    h_gate = gmm_capacity(bins, w_gate, interpret=interpret)
    h_up = gmm_capacity(bins, w_up, interpret=interpret)
    act = jax.nn.gelu if activation == "gelu" else jax.nn.silu
    h = (act(h_gate.astype(jnp.float32)) * h_up.astype(jnp.float32)).astype(x.dtype)
    y_bins = gmm_capacity(h, w_down, interpret=interpret)
    return combine_ref(y_bins, indices, weights, slot, kept)


def gmm(xs: jnp.ndarray, w: jnp.ndarray, group_sizes: jnp.ndarray,
        *, interpret: bool = INTERPRET) -> jnp.ndarray:
    """Sorted-token grouped matmul (N_sorted, D) with per-expert group sizes.

    Ragged groups are re-binned to fixed capacity = max group size rounded to
    128, run through the capacity kernel, and scattered back.  Tokens beyond
    a bin never exist here (capacity == max group size), so this path is
    exact — used by moe.moe_forward(dispatch="gmm") for small/medium N.
    """
    E, D, F = w.shape
    N = xs.shape[0]
    C = _round_up(max(int(N), 1), 128)  # worst case: all tokens on one expert
    offsets = jnp.cumsum(group_sizes) - group_sizes            # (E,)
    # expert id per sorted row, from offsets
    row = jnp.arange(N)
    expert_of_row = jnp.searchsorted(jnp.cumsum(group_sizes), row, side="right")
    slot_of_row = row - offsets[expert_of_row]
    bins = jnp.zeros((E, C, D), xs.dtype).at[expert_of_row, slot_of_row].set(xs)
    y = gmm_capacity(bins, w, interpret=interpret)
    return y[expert_of_row, slot_of_row]

"""Jitted public wrappers around the gmm kernels: capacity dispatch → grouped
matmul → weighted combine, i.e. a full MoE FFN built on the kernel.

Grouped-matmul entry points, fastest first:

  * ``gmm``        — ragged megablox-style kernel (kernels/gmm/ragged.py):
                     no densification, work scales with routed tokens.  The
                     serving default behind ``moe_forward(dispatch="gmm")``.
  * ``gmm_legacy`` — the original bin-to-capacity path kept as a fallback
                     (and as a second oracle): tokens are scattered into
                     fixed ``(E, C)`` bins and run through ``gmm_capacity``.
  * ``moe_ffn_gmm``— capacity-limited full FFN (dispatch → 3 GEMMs →
                     combine); overflow drops are now *counted*, not silent.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels.gmm.gmm import gmm_capacity
from repro.kernels.gmm.ragged import (INTERPRET, fused_gate_up,  # noqa: F401
                                      make_group_metadata, ragged_gmm,
                                      ragged_moe_ffn)
from repro.kernels.gmm.ref import combine_ref, dispatch_ref


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def expert_capacity(n_tokens: int, k: int, num_experts: int,
                    capacity_factor: float = 2.0, align: int = 128) -> int:
    """Fixed per-expert bin size for the capacity-binned paths.

    Parameters
    ----------
    n_tokens : int
        Tokens entering the router (N).
    k : int
        Experts per token (top-K).
    num_experts : int
        Total experts (E).
    capacity_factor : float
        Headroom over the balanced-routing mean N*K/E; the paper (§3.2)
        assumes balanced routing, so 2x keeps drops negligible (validated
        in tests).
    align : int
        Round the bin size up to this multiple (MXU tile alignment).

    Returns
    -------
    int
        Static per-expert bin capacity C.
    """
    mean = n_tokens * k / num_experts
    return max(align, _round_up(int(mean * capacity_factor), align))


@functools.partial(jax.jit, static_argnames=("capacity", "activation",
                                             "interpret", "return_dropped"))
def moe_ffn_gmm(
    x: jnp.ndarray,            # (N, D)
    w_gate: jnp.ndarray,       # (E, D, F)
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,       # (E, F, D)
    weights: jnp.ndarray,      # (N, K) router weights
    indices: jnp.ndarray,      # (N, K) expert ids
    *,
    capacity: int,
    activation: str = "silu",
    interpret: bool = INTERPRET,
    return_dropped: bool = False,
) -> Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Capacity-binned MoE FFN: dispatch → 3 grouped GEMMs → combine.

    Parameters
    ----------
    x : jnp.ndarray
        (N, D) token activations.
    w_gate, w_up : jnp.ndarray
        (E, D, F) per-expert up-projections.
    w_down : jnp.ndarray
        (E, F, D) per-expert down-projection.
    weights, indices : jnp.ndarray
        (N, K) router combine weights and expert ids.
    capacity : int
        Static per-expert bin size (see :func:`expert_capacity`).
    activation : str
        "silu" (default) or "gelu".
    interpret : bool
        Run the Pallas kernels in interpret mode (CPU-correctness path).
    return_dropped : bool
        Also return the number of (token, k) assignments that overflowed
        their expert's bin — deterministic (slot order) but no longer
        silent.

    Returns
    -------
    jnp.ndarray or (jnp.ndarray, jnp.ndarray)
        (N, D) combined output; with ``return_dropped=True`` also the int32
        overflow count.
    """
    E, D, F = w_gate.shape
    N = x.shape[0]
    bins, slot, kept = dispatch_ref(x, indices, E, capacity)
    h_gate = gmm_capacity(bins, w_gate, interpret=interpret)
    h_up = gmm_capacity(bins, w_up, interpret=interpret)
    act = jax.nn.gelu if activation == "gelu" else jax.nn.silu
    h = (act(h_gate.astype(jnp.float32)) * h_up.astype(jnp.float32)).astype(x.dtype)
    y_bins = gmm_capacity(h, w_down, interpret=interpret)
    y = combine_ref(y_bins, indices, weights, slot, kept)
    if return_dropped:
        return y, jnp.sum(~kept).astype(jnp.int32)
    return y


def gmm(xs: jnp.ndarray, w: jnp.ndarray, group_sizes: jnp.ndarray,
        *, interpret: bool = INTERPRET) -> jnp.ndarray:
    """Sorted-token grouped matmul via the ragged Pallas kernel.

    Parameters
    ----------
    xs : jnp.ndarray
        (N_sorted, D) token rows sorted by expert id.
    w : jnp.ndarray
        (E, D, F) per-expert weight matrices.
    group_sizes : jnp.ndarray
        (E,) tokens routed to each expert (sums to N_sorted).
    interpret : bool
        Run the kernel in interpret mode (CPU-correctness path).

    Returns
    -------
    jnp.ndarray
        (N_sorted, F) per-row ``xs[i] @ w[expert_of(i)]``.

    Notes
    -----
    Per-expert offsets are scalar-prefetched and each m-tile looks up its
    expert from the group boundaries — no ``(E, C)`` densification, empty
    experts cost zero tiles, work scales with the routed token count
    (kernels/gmm/ragged.py; tradeoffs in docs/dispatch.md).
    """
    return ragged_gmm(xs, w, group_sizes, interpret=interpret)


def gmm_legacy(xs: jnp.ndarray, w: jnp.ndarray, group_sizes: jnp.ndarray,
               *, capacity: Optional[int] = None,
               interpret: bool = INTERPRET) -> jnp.ndarray:
    """Bin-to-capacity fallback for the ragged kernel.

    Parameters
    ----------
    xs : jnp.ndarray
        (N_sorted, D) token rows sorted by expert id.
    w : jnp.ndarray
        (E, D, F) per-expert weight matrices.
    group_sizes : jnp.ndarray
        (E,) tokens routed to each expert.
    capacity : int, optional
        Static bound on the largest group.  Defaults to
        ``round_up(N, 128)`` — exact for any routing, at worst-case cost;
        callers with a tighter guarantee (e.g. a capacity factor) pass it
        to shrink the bins.
    interpret : bool
        Run the kernel in interpret mode.

    Returns
    -------
    jnp.ndarray
        (N_sorted, F) per-row grouped matmul output.

    Notes
    -----
    Tokens are scattered into fixed-size per-expert bins and run through
    the dense ``gmm_capacity`` kernel.  The ``capacity`` bound is NOT
    checked: a group larger than ``capacity`` has its overflow rows' inputs
    dropped by the scatter and the gather-back clamps their slot to
    ``capacity - 1``, so those output rows silently receive another token's
    result — only pass a capacity you can guarantee.
    """
    E, D, F = w.shape
    N = xs.shape[0]
    C = _round_up(max(int(N), 1), 128) if capacity is None \
        else _round_up(max(int(capacity), 1), 128)
    ends = jnp.cumsum(group_sizes)                              # (E,) once
    offsets = ends - group_sizes
    row = jnp.arange(N)
    expert_of_row = jnp.searchsorted(ends, row, side="right")
    slot_of_row = row - offsets[expert_of_row]
    bins = jnp.zeros((E, C, D), xs.dtype).at[expert_of_row, slot_of_row].set(xs)
    y = gmm_capacity(bins, w, interpret=interpret)
    return y[expert_of_row, slot_of_row]

"""Pure-jnp oracles for the gmm kernel and the capacity dispatch around it."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def gmm_capacity_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(E, C, D) @ (E, D, F) → (E, C, F), plain einsum in f32 accumulation."""
    out = jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32))
    return out.astype(x.dtype)


def dispatch_ref(
    x: jnp.ndarray,            # (N, D) tokens
    indices: jnp.ndarray,      # (N, K) expert ids
    num_experts: int,
    capacity: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Capacity-binned dispatch.

    Returns (bins (E, C, D), slot (N, K) position inside the bin or -1 if
    dropped, kept (N, K) bool).  Position = rank of the (token, k) pair
    among all pairs routed to that expert, in flat (n*K + k) order.
    """
    N, K = indices.shape
    flat = indices.reshape(-1)                                  # (N*K,)
    onehot = jax.nn.one_hot(flat, num_experts, dtype=jnp.int32)  # (NK, E)
    rank = jnp.cumsum(onehot, axis=0) - onehot                  # rank within expert
    slot = jnp.sum(rank * onehot, axis=-1)                      # (NK,)
    kept = slot < capacity
    slot = jnp.where(kept, slot, -1)
    bins = jnp.zeros((num_experts, capacity, x.shape[-1]), x.dtype)
    tok = jnp.repeat(jnp.arange(N), K)
    bins = bins.at[flat, jnp.where(kept, slot, capacity - 1)].add(
        jnp.where(kept[:, None], x[tok], 0).astype(x.dtype)
    )
    return bins, slot.reshape(N, K), kept.reshape(N, K)


def combine_ref(
    y_bins: jnp.ndarray,       # (E, C, F) expert outputs
    indices: jnp.ndarray,      # (N, K)
    weights: jnp.ndarray,      # (N, K)
    slot: jnp.ndarray,         # (N, K)
    kept: jnp.ndarray,         # (N, K)
) -> jnp.ndarray:              # (N, F)
    N, K = indices.shape
    gathered = y_bins[indices.reshape(-1), jnp.maximum(slot.reshape(-1), 0)]
    gathered = jnp.where(kept.reshape(-1)[:, None], gathered, 0)
    w = (weights * kept).reshape(-1)[:, None].astype(gathered.dtype)
    return jnp.sum((gathered * w).reshape(N, K, -1), axis=1)


def _expert_of_row(group_sizes: jnp.ndarray, n: int) -> jnp.ndarray:
    """Expert id per row of an expert-sorted (N, ...) token slab."""
    return jnp.searchsorted(jnp.cumsum(group_sizes), jnp.arange(n),
                            side="right")


def ragged_gmm_ref(xs: jnp.ndarray, w: jnp.ndarray,
                   group_sizes: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the ragged grouped matmul: per-row expert lookup + einsum
    in f32 accumulation.  xs rows are sorted by expert; group_sizes must sum
    to xs.shape[0]."""
    e = _expert_of_row(group_sizes, xs.shape[0])
    out = jnp.einsum("nd,ndf->nf", xs.astype(jnp.float32),
                     w.astype(jnp.float32)[e])
    return out.astype(xs.dtype)


def fused_gate_up_ref(xs, w_gate, w_up, group_sizes, activation="silu"):
    """Oracle for the fused gate+up kernel: act(x@wg) * (x@wu) per group."""
    e = _expert_of_row(group_sizes, xs.shape[0])
    xf = xs.astype(jnp.float32)
    hg = jnp.einsum("nd,ndf->nf", xf, w_gate.astype(jnp.float32)[e])
    hu = jnp.einsum("nd,ndf->nf", xf, w_up.astype(jnp.float32)[e])
    act = jax.nn.gelu(hg, approximate=True) if activation == "gelu" \
        else jax.nn.silu(hg)
    return (act * hu).astype(xs.dtype)


def ragged_moe_ffn_ref(xs, w_gate, w_up, w_down, group_sizes,
                       activation="silu"):
    """Oracle for the 2-launch ragged expert FFN on expert-sorted tokens."""
    h = fused_gate_up_ref(xs, w_gate, w_up, group_sizes, activation)
    return ragged_gmm_ref(h, w_down, group_sizes)


def moe_ffn_ref(x, w_gate, w_up, w_down, weights, indices, activation="silu"):
    """Reference for the whole capacity-free MoE FFN: exact one-hot combine
    (no drops) — the ground truth the capacity path approaches as the
    capacity factor grows."""
    E = w_gate.shape[0]
    act = jax.nn.gelu if activation == "gelu" else jax.nn.silu
    combine = jnp.einsum(
        "nk,nke->ne", weights, jax.nn.one_hot(indices, E, dtype=weights.dtype))
    h = act(jnp.einsum("nd,edf->enf", x, w_gate)) * jnp.einsum("nd,edf->enf", x, w_up)
    y = jnp.einsum("enf,efd->end", h, w_down)
    return jnp.einsum("end,ne->nd", y, combine.astype(y.dtype))

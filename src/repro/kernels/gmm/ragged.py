"""Ragged grouped-matmul Pallas TPU kernels — megablox-style MoE FFN hot path.

Unlike the capacity kernel (gmm.py), tokens are NOT densified into fixed
``(E, C, D)`` bins.  Tokens arrive sorted by expert id; per-expert
``group_offsets`` are scalar-prefetched and drive a grid over
``(n-tiles, m-visits)`` where each m-visit looks up its expert id and row
tile from precomputed group metadata:

  * an m-tile whose rows all belong to one expert is visited once;
  * an m-tile that straddles a group boundary is visited once per group it
    touches, with a row mask so each visit contributes only its own rows;
  * an EMPTY expert contributes zero visits — kernel work scales with the
    actually-routed token count N·K, not with E·C worst-case bins.

The grid's visit axis is padded to the static worst case
``num_m_tiles + E - 1`` (every boundary unaligned); padding visits are
skipped via ``pl.when`` so they cost no MXU work.

``fused_gate_up`` additionally fuses the two up-projections of a
SwiGLU/GeGLU FFN into one launch: each x block is loaded ONCE and both
``x @ w_gate`` and ``x @ w_up`` accumulate into separate VMEM scratch
accumulators; the activation and elementwise product are applied at tile
emission.  Together with the down projection this makes the whole expert
FFN 2 launches instead of 3, halving x HBM reads.

Accumulation is fp32; outputs are cast back to the input dtype — the
oracles in ref.py are the parity spec (see tests/test_ragged_gmm.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Pallas TPU kernels run in interpret mode everywhere but real TPU.
INTERPRET = jax.default_backend() != "tpu"


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _pick_tile(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= pref (MXU lane tile if possible)."""
    for d in range(min(pref, dim), 0, -1):
        if dim % d == 0:
            return d
    return dim


class GroupMetadata(NamedTuple):
    """Scalar-prefetch operands driving the ragged grid (all int32)."""
    group_offsets: jnp.ndarray   # (E+1,) row offsets of each expert's slab
    group_ids: jnp.ndarray       # (T_max,) expert id per visit
    m_tile_ids: jnp.ndarray      # (T_max+1,) m-tile per visit, -1 sentinel last
    num_visits: jnp.ndarray      # (1,) visits that carry real work


def make_group_metadata(group_sizes: jnp.ndarray, n_rows_pad: int,
                        bm: int) -> GroupMetadata:
    """Map a static ``T_max = n_rows_pad/bm + E - 1`` visit axis onto the
    ragged (expert, m-tile) work list.  ``num_visits`` (dynamic) counts the
    visits that do real work: sum over NON-EMPTY experts of the m-tiles their
    row range touches — the tile-count that scales with N·K, not E·C."""
    E = group_sizes.shape[0]
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    tiles = jnp.where(group_sizes > 0, -(-ends // bm) - starts // bm, 0)
    visit_ends = jnp.cumsum(tiles)
    num_visits = visit_ends[-1]
    t_max = n_rows_pad // bm + E - 1
    t = jnp.arange(t_max)
    g = jnp.minimum(jnp.searchsorted(visit_ends, t, side="right"), E - 1)
    mt = starts[g] // bm + (t - (visit_ends[g] - tiles[g]))
    valid = t < num_visits
    # padding visits replay the last real tile (masked to a no-op) so the
    # "last visit of my tile → emit" test stays a single lookahead
    last_tile = mt[jnp.maximum(num_visits - 1, 0)]
    mt = jnp.where(valid, mt, last_tile)
    g = jnp.where(valid, g, E - 1)
    mt_ext = jnp.concatenate([mt, jnp.full((1,), -1, mt.dtype)])
    offsets = jnp.concatenate([jnp.zeros((1,), ends.dtype), ends])
    return GroupMetadata(offsets.astype(jnp.int32), g.astype(jnp.int32),
                         mt_ext.astype(jnp.int32),
                         num_visits[None].astype(jnp.int32))


def _visit_bookkeeping(offs, gids, mtids, nvis, *, bm: int):
    """(first, valid, row_mask, mt) for the current grid step."""
    i = pl.program_id(1)
    g = gids[i]
    mt = mtids[i]
    valid = i < nvis[0]
    first = (i == 0) | (mtids[jnp.maximum(i - 1, 0)] != mt)
    rows = mt * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
    mask = (rows >= offs[g]) & (rows < offs[g + 1])
    return first, valid, mask, mt


def _ragged_kernel(offs, gids, mtids, nvis, x_ref, w_ref, o_ref, acc_ref,
                   *, bm: int):
    first, valid, mask, mt = _visit_bookkeeping(offs, gids, mtids, nvis, bm=bm)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid)
    def _accum():
        prod = jnp.dot(x_ref[...].astype(jnp.float32),
                       w_ref[0].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        acc_ref[...] += jnp.where(mask, prod, 0.0)

    @pl.when(mtids[pl.program_id(1) + 1] != mt)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _fused_kernel(offs, gids, mtids, nvis, x_ref, wg_ref, wu_ref, o_ref,
                  acc_g, acc_u, *, bm: int, activation: str):
    first, valid, mask, mt = _visit_bookkeeping(offs, gids, mtids, nvis, bm=bm)

    @pl.when(first)
    def _init():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)

    @pl.when(valid)
    def _accum():
        x = x_ref[...].astype(jnp.float32)           # loaded once, used twice
        pg = jnp.dot(x, wg_ref[0].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
        pu = jnp.dot(x, wu_ref[0].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
        acc_g[...] += jnp.where(mask, pg, 0.0)
        acc_u[...] += jnp.where(mask, pu, 0.0)

    @pl.when(mtids[pl.program_id(1) + 1] != mt)
    def _emit():
        # act(0)*0 == 0 for silu/gelu, so never-touched (padding) rows emit 0
        g = acc_g[...]
        act = jax.nn.gelu(g, approximate=True) if activation == "gelu" \
            else jax.nn.silu(g)
        o_ref[...] = (act * acc_u[...]).astype(o_ref.dtype)


def _scalar_maps():
    """Index maps for (x, w, out) blocks given the metadata scalar refs."""
    x_map = lambda j, i, offs, gids, mtids, nvis: (mtids[i], 0)
    w_map = lambda j, i, offs, gids, mtids, nvis: (gids[i], 0, j)
    o_map = lambda j, i, offs, gids, mtids, nvis: (mtids[i], j)
    return x_map, w_map, o_map


def _row_tile(n: int, bm: int, dtype) -> int:
    sub = 16 if dtype == jnp.bfloat16 else 8
    return min(bm, max(sub, _round_up(n, sub)))


def _ragged_call(xs_pad, ws, meta: GroupMetadata, kernel, n_acc: int,
                 out_f: int, *, bm: int, bn: int, interpret: bool):
    """Shared pallas_call plumbing for the single and fused kernels."""
    n_pad, d = xs_pad.shape
    E = ws[0].shape[0]
    t_max = n_pad // bm + E - 1
    x_map, w_map, o_map = _scalar_maps()
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(out_f // bn, t_max),
            in_specs=[pl.BlockSpec((bm, d), x_map)]
            + [pl.BlockSpec((1, d, bn), w_map) for _ in ws],
            out_specs=pl.BlockSpec((bm, bn), o_map),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)] * n_acc,
        ),
        out_shape=jax.ShapeDtypeStruct((n_pad, out_f), xs_pad.dtype),
        interpret=interpret,
    )(*meta, xs_pad, *ws)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def ragged_gmm(xs: jnp.ndarray,           # (N, D) tokens sorted by expert
               w: jnp.ndarray,            # (E, D, F) expert weights
               group_sizes: jnp.ndarray,  # (E,) rows per expert
               *, bm: int = 128, bn: int = 128,
               interpret: bool = INTERPRET) -> jnp.ndarray:   # (N, F)
    N, D = xs.shape
    E, _, F = w.shape
    bm = _row_tile(N, bm, xs.dtype)
    bn = _pick_tile(F, bn)
    n_pad = _round_up(N, bm)
    xs_pad = jnp.pad(xs, ((0, n_pad - N), (0, 0)))
    meta = make_group_metadata(group_sizes, n_pad, bm)
    out = _ragged_call(xs_pad, (w,), meta,
                       functools.partial(_ragged_kernel, bm=bm),
                       1, F, bm=bm, bn=bn, interpret=interpret)
    return out[:N]


@functools.partial(jax.jit,
                   static_argnames=("activation", "bm", "bn", "interpret"))
def fused_gate_up(xs: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                  group_sizes: jnp.ndarray, *, activation: str = "silu",
                  bm: int = 128, bn: int = 128,
                  interpret: bool = INTERPRET) -> jnp.ndarray:
    """act(xs @ w_gate[g]) * (xs @ w_up[g]) in ONE launch: (N, D) → (N, F)."""
    N, D = xs.shape
    E, _, F = w_gate.shape
    bm = _row_tile(N, bm, xs.dtype)
    bn = _pick_tile(F, bn)
    n_pad = _round_up(N, bm)
    xs_pad = jnp.pad(xs, ((0, n_pad - N), (0, 0)))
    meta = make_group_metadata(group_sizes, n_pad, bm)
    out = _ragged_call(
        xs_pad, (w_gate, w_up), meta,
        functools.partial(_fused_kernel, bm=bm, activation=activation),
        2, F, bm=bm, bn=bn, interpret=interpret)
    return out[:N]


@functools.partial(jax.jit,
                   static_argnames=("activation", "bm", "bn", "interpret"))
def ragged_moe_ffn(xs: jnp.ndarray,       # (N, D) tokens sorted by expert
                   w_gate: jnp.ndarray, w_up: jnp.ndarray,
                   w_down: jnp.ndarray,   # (E, F, D)
                   group_sizes: jnp.ndarray, *, activation: str = "silu",
                   bm: int = 128, bn: int = 128,
                   interpret: bool = INTERPRET) -> jnp.ndarray:
    """Whole expert FFN on expert-sorted tokens in 2 launches (fused gate+up,
    then down).  Group metadata is built once and shared."""
    N, D = xs.shape
    E, _, F = w_gate.shape
    bm = _row_tile(N, bm, xs.dtype)
    n_pad = _round_up(N, bm)
    xs_pad = jnp.pad(xs, ((0, n_pad - N), (0, 0)))
    meta = make_group_metadata(group_sizes, n_pad, bm)
    h = _ragged_call(
        xs_pad, (w_gate, w_up), meta,
        functools.partial(_fused_kernel, bm=bm, activation=activation),
        2, F, bm=bm, bn=_pick_tile(F, bn), interpret=interpret)
    y = _ragged_call(h, (w_down,), meta,
                     functools.partial(_ragged_kernel, bm=bm),
                     1, D, bm=bm, bn=_pick_tile(D, bn), interpret=interpret)
    return y[:N]


def _selfcheck() -> None:
    """Interpret-mode parity smoke vs the ref.py oracles (scripts/ci.sh)."""
    import numpy as np

    from repro.kernels.gmm.ref import fused_gate_up_ref, ragged_gmm_ref

    sizes = jnp.array([70, 0, 1, 57], jnp.int32)
    N = int(sizes.sum())
    E, D, F = 4, 64, 96
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    xs = jax.random.normal(ks[0], (N, D), jnp.float32)
    wg = jax.random.normal(ks[1], (E, D, F), jnp.float32) / np.sqrt(D)
    wu = jax.random.normal(ks[2], (E, D, F), jnp.float32) / np.sqrt(D)
    np.testing.assert_allclose(
        np.asarray(ragged_gmm(xs, wg, sizes, interpret=True)),
        np.asarray(ragged_gmm_ref(xs, wg, sizes)), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(fused_gate_up(xs, wg, wu, sizes, interpret=True)),
        np.asarray(fused_gate_up_ref(xs, wg, wu, sizes)),
        rtol=1e-4, atol=1e-4)
    visits = int(make_group_metadata(sizes, _round_up(N, 128), 128).num_visits[0])
    assert visits <= 3 + 1, visits   # 1 full m-tile + 3 boundary straddles
    print(f"ragged kernel parity OK (N={N}, visits={visits})")


if __name__ == "__main__":
    _selfcheck()

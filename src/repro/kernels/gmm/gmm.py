"""Grouped (per-expert) matmul Pallas TPU kernel — the MoE FFN hot spot.

TPU adaptation of the paper's expert GEMMs (DESIGN.md §2): tokens are
dispatched to fixed-capacity expert bins (E, C, D) — justified by the
paper's own balanced-routing assumption (§3.2) — turning the ragged
grouped matmul into a regular batched matmul that tiles onto the MXU:

    out[e] = x[e] @ w[e]        x: (E, C, D), w: (E, D, F) → (E, C, F)

Grid is (E, C/bm, F/bn, D/bk), row-major ⇒ the K dimension is innermost;
a float32 VMEM accumulator persists across K steps (init at k==0, emit at
k==nk−1).  Block sizes default to MXU-aligned 128×128×512 and the three
live blocks (x, w, acc) fit comfortably in the 16 MiB v5e VMEM:
128·512·2 + 512·128·2 + 128·128·4 ≈ 0.3 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _emit():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def gmm_capacity(
    x: jnp.ndarray,          # (E, C, D) dispatched tokens
    w: jnp.ndarray,          # (E, D, F) expert weights
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:            # (E, C, F)
    E, C, D = x.shape
    _, _, F = w.shape
    bm, bn, bk = min(bm, C), min(bn, F), min(bk, D)
    assert C % bm == 0 and F % bn == 0 and D % bk == 0, (x.shape, w.shape, (bm, bn, bk))
    nk = D // bk
    grid = (E, C // bm, F // bn, nk)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)

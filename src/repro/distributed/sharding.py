"""Sharding rules: parameter / cache / activation PartitionSpecs.

Logical placement (DESIGN.md §7):

  * tensor-parallel ("model" axis):  attention heads, FFN hidden dim,
    vocab dim of embed/head; MoE experts are EXPERT-parallel — the expert
    axis shards over "model", the paper's EP deployment (§3.4: EP changes
    neither N(t) nor T̄_exp, so the MoESD analysis carries over unchanged).
  * batch-parallel ("pod","data"): batch dim of activations and caches.
  * FSDP (train mode): parameters additionally shard their largest
    remaining dim over ("pod","data"); optimizer moments inherit.

Every rule degrades gracefully: if a dim is not divisible by the axis size
the axis is dropped (replicated) — this is what lets all 40 arch x shape
combinations lower on the same mesh without per-arch special-casing.

Scan-stacked layer params carry a leading (num_periods,) axis → specs are
prefixed with None.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    import math
    return math.prod(mesh.shape[a] for a in axes)


def _fit(mesh: Mesh, spec: P, shape) -> P:
    """Drop sharded axes whose dim is not divisible by the axis size."""
    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
        elif dim % _axis_size(mesh, axes) == 0 and dim > 0:
            out.append(axes)
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# (path regex, spec WITHOUT the leading scan axis). First match wins.
# "D" placeholder = FSDP axes in train mode, None in serve mode.
_PARAM_RULES = [
    # MoE experts: expert-parallel over "model"; FSDP over the big d_ff dim
    (r"ffn/(w_gate|w_up|w_down)$", ("model", None, "D")),
    (r"ffn/router$", (None, None)),
    (r"shared/(w_gate|w_up)$", ("D", "model")),
    (r"shared/w_down$", ("model", "D")),
    # dense FFN: megatron column/row split
    (r"(^|/)(w_gate|w_up|w_ffn_up)$", ("D", "model")),
    (r"(^|/)(w_down|w_ffn_down)$", ("model", "D")),
    # attention projections
    (r"(wq|wk|wv|w_uq|w_uk|w_uv)$", ("D", "model")),
    (r"(wo)$", ("model", "D")),
    (r"(bq|bk|bv)$", ("model",)),
    (r"(w_dkv|w_dq)$", ("D", None)),
    # ssm / xlstm
    (r"mixer/w_in$", ("D", "model")),
    (r"mixer/(w_out|w_down)$", ("model", "D")),
    (r"mixer/w_up$", ("D", "model")),
    (r"mixer/(conv_w|conv_b)$", (None,)),
    (r"mixer/(w_xdbc|w_dt|A_log|dt_bias|D)$", ("model",)),
    (r"mixer/(w_i|w_f|i_bias|f_bias)$", (None,)),
    (r"(r_z|r_i|r_f|r_o|w_z)$", ("D", "model")),
    # embeddings / head: vocab over model
    (r"(embed|head)/table$", ("model", "D")),
    # norms & everything small: replicated
    (r".*", (None,)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path_str: str, shape, *, mesh: Mesh, fsdp: bool,
               stacked: bool, fsdp_min_size: int = 0,
               layout: str = "tp") -> P:
    """layout:
      "tp"   — Megatron TP over "model" + FSDP over ("pod","data")  [default]
      "fsdp" — no tensor parallelism: every axis (incl. "model") is a batch/
               FSDP axis; dense weights shard their FSDP dim over ALL axes.
               Trades per-layer activation all-reduces for parameter
               all-gathers — wins when tokens/step ≫ params (§Perf B1)."""
    import math
    def _matches(pat, spec):
        """Rule applies if the pattern hits AND the spec rank fits the leaf
        (dense FFN and MoE expert weights share ffn/w_* paths; ranks differ:
        2D dense vs 3D (E, d, f) experts)."""
        if not re.search(pat, path_str):
            return False
        want = len(spec) + (1 if stacked else 0)
        return want <= len(shape) or len(spec) <= 1

    if layout == "fsdp":
        all_axes = tuple(mesh.axis_names)
        d_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        n_elem = math.prod(shape) if shape else 0
        ok = fsdp and n_elem >= fsdp_min_size
        for pat, spec in _PARAM_RULES:
            if _matches(pat, spec):
                # MoE expert weights ("model", None, "D"): experts STAY on
                # the model axis (EP needs it), FSDP over the data axes.
                is_expert = len(spec) == 3 and spec[0] == "model"
                resolved = []
                assigned = False
                for s in spec:
                    if is_expert:
                        if s == "model":
                            resolved.append("model")
                        elif s == "D":
                            resolved.append(d_axes if ok else None)
                        else:
                            resolved.append(None)
                    elif s in ("model", "D") and not assigned:
                        # dense weights: no TP — one dim shards over ALL axes
                        resolved.append(all_axes if ok else None)
                        assigned = True
                    else:
                        resolved.append(None)
                if stacked:
                    resolved = [None] + resolved
                return _fit(mesh, P(*resolved), shape)
        return P()
    d_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    n_elem = math.prod(shape) if shape else 0
    fs = d_axes if (fsdp and d_axes and n_elem >= fsdp_min_size) else None
    for pat, spec in _PARAM_RULES:
        if _matches(pat, spec):
            resolved = tuple(fs if s == "D" else s for s in spec)
            if stacked:
                resolved = (None,) + resolved
            return _fit(mesh, P(*resolved), shape)
    return P()


def shard_params(params, mesh: Mesh, *, fsdp: bool = False,
                 fsdp_min_size: int = 0, layout: str = "tp"):
    """Pytree of NamedSharding for a params tree (layers/* are scan-stacked).

    ``fsdp_min_size``: leaves smaller than this (elements) skip the FSDP
    axis — small weights are cheaper to replicate than to all-gather every
    layer (a §Perf lever)."""

    def spec_of(path, leaf):
        ps = _path_str(path)
        stacked = ps.startswith("layers") or "/layers/" in ps
        return NamedSharding(mesh, param_spec(ps, leaf.shape, mesh=mesh,
                                              fsdp=fsdp, stacked=stacked,
                                              fsdp_min_size=fsdp_min_size,
                                              layout=layout))

    return jax.tree_util.tree_map_with_path(spec_of, params)


# ---------------------------------------------------------------------------
# cache rules
# ---------------------------------------------------------------------------

def cache_spec(path_str: str, shape, *, mesh: Mesh, kv_mode: str = "auto") -> P:
    """KV/state caches: leading (P periods, B, ...).

    Batch shards over ("pod","data") when divisible.  ``kv_mode``:
      auto  — head axis over "model" when divisible, else sequence axis
              (flash-decoding style; XLA inserts the partial-softmax combine)
      seq   — always shard the sequence axis over "model"
      heads — shard heads (replicating when non-divisible)"""
    d_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data")) or None
    msize = mesh.shape["model"]
    if re.search(r"lengths$", path_str):
        return _fit(mesh, P(d_axes), shape)
    if re.search(r"pages/table$", path_str):
        # (B, max_pages) logical→physical block table: rows follow batch
        return _fit(mesh, P(d_axes, None), shape)
    if re.search(r"(k|v)_pages$", path_str) and len(shape) == 5:
        # (P, pool, page, Hkv, hd) physical page pool: pages are a SHARED
        # pool addressed through the table (page ids carry no batch
        # locality), so only the head axis shards — over "model"
        return _fit(mesh, P(None, None, None, "model", None), shape)
    if re.search(r"(latent|k_rope)_pages$", path_str):
        # (P, pool, page, dim) MLA page pools: replicated pool
        return P(*([None] * len(shape)))
    if re.search(r"(^|/)(k|v)$", path_str) and len(shape) == 5:
        # (P, B, S, Hkv, hd)
        head_ok = shape[3] % msize == 0
        if kv_mode == "seq" or (kv_mode == "auto" and not head_ok):
            return _fit(mesh, P(None, d_axes, "model", None, None), shape)
        return _fit(mesh, P(None, d_axes, None, "model", None), shape)
    if re.search(r"pos$", path_str) and len(shape) == 3:
        return _fit(mesh, P(None, d_axes, None), shape)
    if re.search(r"(latent|k_rope)$", path_str) and len(shape) == 4:
        return _fit(mesh, P(None, d_axes, "model", None), shape)   # seq-sharded
    if re.search(r"(^|/)(conv|ssm|C|n|m|c|h)$", path_str):
        # recurrent states: (P, B, ...) — shard batch; biggest state dim on model
        spec = [None, d_axes] + [None] * (len(shape) - 2)
        for i in range(2, len(shape)):
            if shape[i] % msize == 0:
                spec[i] = "model"
                break
        return _fit(mesh, P(*spec), shape)
    return _fit(mesh, P(None, d_axes), shape)


def shard_cache(cache, mesh: Mesh, kv_mode: str = "auto"):
    """NamedSharding pytree for a session KV cache: batch dims over the
    data axes, KV heads (dense layers and paged pools) over "model" where
    divisible; one ``cache_spec`` rule per leaf path."""
    def spec_of(path, leaf):
        return NamedSharding(mesh, cache_spec(_path_str(path), leaf.shape,
                                              mesh=mesh, kv_mode=kv_mode))

    return jax.tree_util.tree_map_with_path(spec_of, cache)


# ---------------------------------------------------------------------------
# activations / batches / optimizer state
# ---------------------------------------------------------------------------

def batch_sharding(mesh: Mesh, tree, layout: str = "tp"):
    """tokens/labels/mask (B, T) and embeds (B, T, d): batch over data axes
    (every axis in the "fsdp" layout)."""
    if layout == "fsdp":
        d_axes = tuple(mesh.axis_names) or None
    else:
        d_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data")) or None

    def spec_of(path, leaf):
        spec = P(d_axes, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, _fit(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(spec_of, tree)


def shard_opt_state(opt_state, params_shardings, mesh: Mesh):
    """Adam moments inherit parameter shardings; step is replicated."""
    from repro.training.optimizer import AdamState
    return AdamState(
        step=NamedSharding(mesh, P()),
        mu=params_shardings,
        nu=params_shardings,
    )

"""Activation sharding constraints (MaxText-style logical annotations).

GSPMD propagates shardings from inputs, but at contraction points with
FSDP-sharded weights it can resolve conflicts by replicating activations
(observed: the loss head replicated (B, chunk, vocab) logits because the
embedding table's d-dim carried the 'data' axis).  Explicit constraints at
a few strategic points pin the batch axis to ("pod","data") and let the
partitioner all-gather weights instead.

The mesh is threaded EXPLICITLY: ``Model(cfg, mesh=...)`` (and
``ServingEngine(..., mesh=...)`` above it) hands the mesh to every
``constrain`` call, so model code carries no hidden global state and the
analyzer's captured-state rule (T106) holds without waivers.  The old
process-global fallback (``set_mesh``) is REMOVED: calling it raises, and
the analyzer's S405 rule flags any caller statically.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

VALID_LAYOUTS = ("tp", "fsdp")


def _validate(mesh: Optional[Mesh], layout: str) -> None:
    if layout not in VALID_LAYOUTS:
        raise ValueError(f"layout must be one of {VALID_LAYOUTS}, got {layout!r}")
    if mesh is not None and not isinstance(mesh, Mesh):
        raise TypeError(f"mesh must be a jax.sharding.Mesh, got {type(mesh)!r}")


def set_mesh(mesh: Optional[Mesh], layout: str = "tp"):
    """REMOVED: the process-global mesh fallback no longer exists.

    Thread the mesh explicitly instead — ``Model(cfg, mesh=...)`` /
    ``ServingEngine(..., mesh=...)`` — so sharding is visible at the call
    site and carries no process-global state.  Calling this always raises
    ``RuntimeError`` (the static analyzer flags callers as S405 before
    they get this far).
    """
    raise RuntimeError(
        "set_mesh was removed: pass mesh=/mesh_layout= explicitly "
        "(Model(cfg, mesh=...), ServingEngine(..., mesh=...))")


def resolve_mesh(mesh: Optional[Mesh] = None,
                 layout: Optional[str] = None
                 ) -> Tuple[Optional[Mesh], str]:
    """Validate and normalize an explicitly threaded (mesh, layout) pair.
    ``mesh=None`` means single-device: there is no process-global
    fallback to consult any more."""
    if mesh is not None:
        _validate(mesh, layout or "tp")
        return mesh, (layout or "tp")
    if layout is not None:
        _validate(None, layout)
    return None, (layout or "tp")


def data_axes_of(mesh, layout: str):
    """Batch-parallel axes under a layout: every axis for fsdp, the
    ("pod","data") subset for tp.  None when the mesh has no such axes."""
    if layout == "fsdp":
        return tuple(mesh.axis_names) or None
    return tuple(a for a in mesh.axis_names if a in ("pod", "data")) or None


def constrain(x, kind: str, *, mesh: Optional[Mesh] = None,
              layout: Optional[str] = None):
    """Pin an activation's sharding: 'hidden' (batch-major activation) |
    'logits' (vocab-last).  No-op when neither an explicit ``mesh`` nor the
    deprecated ``set_mesh`` fallback is configured."""
    mesh, layout = resolve_mesh(mesh, layout)
    if mesh is None:
        return x
    d_axes = data_axes_of(mesh, layout)
    d_size = math.prod(mesh.shape[a] for a in (d_axes or ()))
    if x.shape[0] % max(d_size, 1) != 0:
        d_axes = None
    if kind == "logits":
        m_size = mesh.shape.get("model", 1)
        vocab_axis = ("model" if layout == "tp" and x.shape[-1] % m_size == 0
                      else None)
        spec = P(d_axes, *([None] * (x.ndim - 2)), vocab_axis)
    else:
        spec = P(d_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

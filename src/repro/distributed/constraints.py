"""Activation sharding constraints (MaxText-style logical annotations).

GSPMD propagates shardings from inputs, but at contraction points with
FSDP-sharded weights it can resolve conflicts by replicating activations
(observed: the loss head replicated (B, chunk, vocab) logits because the
embedding table's d-dim carried the 'data' axis).  Explicit constraints at
a few strategic points pin the batch axis to ("pod","data") and let the
partitioner all-gather weights instead.

The module is a process-global switch so model code stays mesh-agnostic:
launch code calls ``set_mesh(mesh)``; tests/single-device runs leave it
unset and ``constrain`` is a no-op.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None
_LAYOUT: str = "tp"


def set_mesh(mesh: Optional[Mesh], layout: str = "tp"):
    global _MESH, _LAYOUT
    _MESH = mesh
    _LAYOUT = layout


def get_mesh() -> Optional[Mesh]:
    return _MESH


def get_layout() -> str:
    return _LAYOUT


def _data_axes(mesh):
    if _LAYOUT == "fsdp":
        return tuple(mesh.axis_names) or None
    return tuple(a for a in mesh.axis_names if a in ("pod", "data")) or None


def constrain(x, kind: str):
    """kind: 'hidden' (batch-major activation) | 'logits' (vocab-last)."""
    mesh = _MESH
    if mesh is None:
        return x
    d_axes = _data_axes(mesh)
    import math
    d_size = math.prod(mesh.shape[a] for a in (d_axes or ()))
    if x.shape[0] % max(d_size, 1) != 0:
        d_axes = None
    if kind == "logits":
        m_size = mesh.shape.get("model", 1)
        vocab_axis = ("model" if _LAYOUT == "tp" and x.shape[-1] % m_size == 0
                      else None)
        spec = P(d_axes, *([None] * (x.ndim - 2)), vocab_axis)
    else:
        spec = P(d_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

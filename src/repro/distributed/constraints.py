"""Activation sharding constraints (MaxText-style logical annotations).

GSPMD propagates shardings from inputs, but at contraction points with
FSDP-sharded weights it can resolve conflicts by replicating activations
(observed: the loss head replicated (B, chunk, vocab) logits because the
embedding table's d-dim carried the 'data' axis).  Explicit constraints at
a few strategic points pin the batch axis to ("pod","data") and let the
partitioner all-gather weights instead.

The mesh is threaded EXPLICITLY: ``Model(cfg, mesh=...)`` (and
``ServingEngine(..., mesh=...)`` above it) hands the mesh to every
``constrain`` call, so model code carries no hidden global state and the
analyzer's captured-state rule (T106) holds without waivers.  A validated
process-global fallback (``set_mesh``) survives, deprecated, for launch
scripts that configure sharding once at startup; new code should pass
``mesh=`` instead.
"""
from __future__ import annotations

import math
import warnings
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

VALID_LAYOUTS = ("tp", "fsdp")

# Deprecated process-global fallback — written ONLY by set_mesh (host-side,
# never inside a trace), read only when no explicit mesh is threaded.
_MESH: Optional[Mesh] = None
_LAYOUT: str = "tp"


def _validate(mesh: Optional[Mesh], layout: str) -> None:
    if layout not in VALID_LAYOUTS:
        raise ValueError(f"layout must be one of {VALID_LAYOUTS}, got {layout!r}")
    if mesh is not None and not isinstance(mesh, Mesh):
        raise TypeError(f"mesh must be a jax.sharding.Mesh, got {type(mesh)!r}")


def set_mesh(mesh: Optional[Mesh], layout: str = "tp"):
    """DEPRECATED: install a process-global mesh for ``constrain`` fallback.

    Thread the mesh explicitly instead — ``Model(cfg, mesh=...)`` /
    ``ServingEngine(..., mesh=...)`` — so sharding is visible at the call
    site and carries no process-global state.  Arguments are validated
    (Mesh instance, layout in ``VALID_LAYOUTS``); ``set_mesh(None)``
    clears the fallback.
    """
    global _MESH, _LAYOUT
    _validate(mesh, layout)
    warnings.warn(
        "set_mesh is deprecated: pass mesh=/mesh_layout= explicitly "
        "(Model(cfg, mesh=...), ServingEngine(..., mesh=...))",
        DeprecationWarning, stacklevel=2)
    _MESH = mesh
    _LAYOUT = layout


def get_mesh() -> Optional[Mesh]:
    return _MESH


def get_layout() -> str:
    return _LAYOUT


def resolve_mesh(mesh: Optional[Mesh] = None,
                 layout: Optional[str] = None
                 ) -> Tuple[Optional[Mesh], str]:
    """Resolve (mesh, layout): the explicit arguments when given, else the
    deprecated ``set_mesh`` process-global fallback."""
    if mesh is not None:
        _validate(mesh, layout or "tp")
        return mesh, (layout or "tp")
    return _MESH, (layout if layout is not None else _LAYOUT)


def data_axes_of(mesh, layout: str):
    """Batch-parallel axes under a layout: every axis for fsdp, the
    ("pod","data") subset for tp.  None when the mesh has no such axes."""
    if layout == "fsdp":
        return tuple(mesh.axis_names) or None
    return tuple(a for a in mesh.axis_names if a in ("pod", "data")) or None


def constrain(x, kind: str, *, mesh: Optional[Mesh] = None,
              layout: Optional[str] = None):
    """Pin an activation's sharding: 'hidden' (batch-major activation) |
    'logits' (vocab-last).  No-op when neither an explicit ``mesh`` nor the
    deprecated ``set_mesh`` fallback is configured."""
    mesh, layout = resolve_mesh(mesh, layout)
    if mesh is None:
        return x
    d_axes = data_axes_of(mesh, layout)
    d_size = math.prod(mesh.shape[a] for a in (d_axes or ()))
    if x.shape[0] % max(d_size, 1) != 0:
        d_axes = None
    if kind == "logits":
        m_size = mesh.shape.get("model", 1)
        vocab_axis = ("model" if layout == "tp" and x.shape[-1] % m_size == 0
                      else None)
        spec = P(d_axes, *([None] * (x.ndim - 2)), vocab_axis)
    else:
        spec = P(d_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

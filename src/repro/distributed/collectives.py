"""Expert-parallel MoE dispatch via shard_map (the §Perf optimization).

The baseline one-hot dispatch (models/moe.py) runs EVERY token through
EVERY expert — E/K-fold redundant compute (usefulness ≈ K/E in the
roofline table) that GSPMD cannot eliminate.  This module dispatches
routed tokens through an explicit two-hop ``all_to_all`` into the
*per-shard ragged grouped matmul*:

  * expert weights are sharded over the "model" axis (E/m experts/shard),
  * token rows are sharded over EVERY mesh axis (each of the n shards
    routes a disjoint slice),
  * each shard ranks its (token, k) pairs per destination shard and
    all-to-alls the token payloads to the shards owning the chosen
    experts — per-DEST-shard slot buffers, NOT dense (E, C) capacity
    bins,
  * the receiving shard sorts arrivals by LOCAL expert id and runs the
    ragged gmm kernel (kernels/gmm/ragged.py) with local group sizes —
    expert GEMM work scales with the tokens actually received, and an
    EMPTY local expert costs zero tiles,
  * the shared-expert matmul runs on local rows BETWEEN the two a2a hops,
    so the combine is staggered and the compiler can hide the collectives
    under independent compute (the TensorRT-LLM NCCL-overlap idiom),
  * the return all-to-all brings each pair's expert output home, where it
    is combined against the top-k router weights.

Per-layer collective cost: 2 × (N·K·d / ep_degree) elements per device
(dispatch + combine) — priced by ``SpeedupModel.ep_a2a_time``
(core/perf_model.py) and reported per wave by ``ep_load_report``.

Used with ``Model(cfg, moe_dispatch="ep", mesh=...)``; the mesh is
threaded explicitly (docs/distributed.md) — the old ``constraints.set_mesh``
process-global is removed.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.constraints import resolve_mesh


def _act(x, activation):
    return jax.nn.gelu(x, approximate=True) if activation == "gelu" else jax.nn.silu(x)


def _ragged_ep_shard(x, router_w, w_gate, w_up, w_down, shared, *,
                     cfg, slots: int, activation: str,
                     model_axis: str, m_shards: int, interpret):
    """shard_map body: route local rows, all-to-all routed payloads to the
    shards owning the chosen experts, run the LOCAL ragged gmm slice, and
    all-to-all the results back for the top-k weighted combine.

    x: (N_loc, d) this shard's disjoint token rows; w_*: (e_local, d, f)
    this shard's expert slice; shared: () or replicated shared-expert
    weights, computed between the two a2a hops so the collectives overlap
    independent compute instead of serializing with it.
    """
    from repro.kernels.gmm import ops as gmm_ops
    from repro.models.moe import router_topk

    N, d = x.shape
    e_local = w_gate.shape[0]
    top_k = cfg.num_experts_per_tok

    # 1. route local rows with the full (replicated) router — the same
    #    router_topk the single-device dispatches use (renormalized
    #    top-k, fp32), so routing decisions match bit-for-bit
    weights, indices, _ = router_topk({"router": router_w}, cfg, x)

    # 2. rank each (token, k) pair within its DESTINATION shard — the slot
    #    buffer is per dest shard, not per expert: no dense (E, C) staging
    flat_e = indices.reshape(-1)                   # (N*K,) global expert ids
    dest = flat_e // e_local                       # owning shard per pair
    tok = jnp.repeat(jnp.arange(N), top_k)
    onehot = jax.nn.one_hot(dest, m_shards, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.take_along_axis(rank, dest[:, None], axis=1)[:, 0]
    kept = slot < slots                            # all-True when slots=N*K
    dest_eff = jnp.where(kept, dest, m_shards)     # OOB → scatter drops
    slot = jnp.where(kept, slot, 0)

    # 3. dispatch a2a: token payloads + their LOCAL expert id on the dest
    #    shard (e_local marks an empty slot → pad group after the sort)
    send = jnp.zeros((m_shards, slots, d), x.dtype)
    send = send.at[dest_eff, slot].set(x[tok], mode="drop")
    send_eid = jnp.full((m_shards, slots), e_local, jnp.int32)
    send_eid = send_eid.at[dest_eff, slot].set(
        flat_e % e_local, mode="drop")
    recv = jax.lax.all_to_all(send, model_axis, split_axis=0, concat_axis=0)
    recv_eid = jax.lax.all_to_all(send_eid, model_axis,
                                  split_axis=0, concat_axis=0)

    # 4. shared-expert branch on local rows — no data dependence on the
    #    a2a, so the scheduler hides the dispatch hop under this matmul
    #    (and the combine below is staggered after it)
    shared_out = None
    if shared:  # lint: allow[T101] tuple-or-None closure structure: truthiness is trace-time shape, not data
        sg, su, sd_ = shared
        shared_out = (_act(x @ sg, activation) * (x @ su)) @ sd_

    # 5. per-shard ragged FFN: sort arrivals by local expert id, local
    #    group sizes drive the kernel — empty local experts cost nothing,
    #    pad slots sort into a trailing group no expert owns
    xs = recv.reshape(m_shards * slots, d)
    eid = recv_eid.reshape(-1)
    order = jnp.argsort(eid)                       # stable: preserves (src, slot)
    sizes = jnp.bincount(eid, length=e_local + 1)[:e_local]
    ys = gmm_ops.ragged_moe_ffn(xs[order], w_gate, w_up, w_down, sizes,
                                activation=activation, interpret=interpret)
    real = jnp.arange(m_shards * slots) < jnp.sum(sizes)
    ys = jnp.where(real[:, None], ys, 0).astype(x.dtype)
    back = jnp.zeros_like(ys).at[order].set(ys).reshape(m_shards, slots, d)

    # 6. return a2a, then combine against the top-k weights (fp32 accum)
    ret = jax.lax.all_to_all(back, model_axis, split_axis=0, concat_axis=0)
    gathered = ret[jnp.where(kept, dest, 0), slot]
    wk = weights.reshape(-1) * kept
    out = jnp.zeros((N, d), jnp.float32)
    out = out.at[tok].add(gathered.astype(jnp.float32) * wk[:, None])
    out = out.astype(x.dtype)
    if shared_out is not None:
        out = out + shared_out
    return out


def moe_ep_forward(params: dict, cfg, x: jnp.ndarray, *,
                   mesh=None, layout: Optional[str] = None,
                   capacity_factor: Optional[float] = None,
                   interpret: Optional[bool] = None):
    """(B, T, d) → (B, T, d) expert-parallel MoE FFN.

    Token rows shard over every mesh axis; each shard all-to-alls its
    routed (token, k) payloads to the shards owning the chosen experts,
    which run the ragged gmm over their local expert slice (module
    docstring has the full contract).  ``capacity_factor=None`` (default)
    sizes the per-destination slot buffers to the drop-free worst case
    N_loc·K, making outputs token-identical to the single-device gmm
    dispatch; a finite factor trades a2a volume for possible drops under
    extreme skew.  Falls back to the dense one-hot path when no mesh is
    threaded (single-device tests) or E does not divide over the model
    axis.
    """
    mesh, layout = resolve_mesh(mesh, layout)
    if mesh is None or "model" not in mesh.axis_names \
            or cfg.num_experts % mesh.shape["model"] != 0:
        from repro.models import moe as moe_mod
        return moe_mod.moe_forward(params, cfg, x, dispatch="onehot")[0]
    if interpret is None:
        from repro.kernels.gmm.ragged import INTERPRET
        interpret = INTERPRET

    B, T, d = x.shape
    m = mesh.shape["model"]
    all_axes = tuple(mesh.axis_names)
    n_shards = math.prod(mesh.shape[a] for a in all_axes)
    K = cfg.num_experts_per_tok

    # row-shard tokens over the whole mesh; pad to an even split (pad rows
    # are zero vectors — routed, computed, sliced off: correctness is
    # unaffected, and no-pad is the common case at serving batch sizes)
    xf = x.reshape(B * T, d)
    n_pad = -(-(B * T) // n_shards) * n_shards
    if n_pad != B * T:
        xf = jnp.pad(xf, ((0, n_pad - B * T), (0, 0)))
    n_local = n_pad // n_shards
    if capacity_factor is None:
        slots = n_local * K                        # drop-free (token-identical)
    else:
        want = -(-int(n_local * K * capacity_factor) // m)
        slots = max(8, min(n_local * K, -(-want // 8) * 8))

    has_shared = "shared" in params
    shared_w = ((params["shared"]["w_gate"], params["shared"]["w_up"],
                 params["shared"]["w_down"]) if has_shared else ())
    in_specs = (P(all_axes, None),                 # tokens: disjoint rows
                P(),                               # router (replicated)
                P("model", None, None), P("model", None, None),
                P("model", None, None),            # expert slices
                (P(), P(), P()) if has_shared else ())
    fn = shard_map(
        partial(_ragged_ep_shard, cfg=cfg, slots=slots,
                activation=cfg.mlp_activation, model_axis="model",
                m_shards=m, interpret=interpret),
        mesh=mesh, in_specs=in_specs, out_specs=P(all_axes, None),
        check_rep=False)
    y = fn(xf, params["router"], params["w_gate"], params["w_up"],
           params["w_down"], shared_w)
    return y[:B * T].reshape(B, T, d)


def ep_a2a_bytes(tokens: int, top_k: int, d_model: int, ep_degree: int,
                 *, dtype_bytes: int = 2) -> float:
    """Per-device all-to-all volume of one EP MoE layer: each routed copy
    crosses the interconnect twice (dispatch + combine), N·K·d·2·bytes
    total, split over ep_degree devices."""
    if ep_degree <= 1:
        return 0.0
    return 2.0 * tokens * top_k * d_model * dtype_bytes / ep_degree


def ep_load_report(params: dict, cfg, tokens, ep_degree: int,
                   *, dtype_bytes: Optional[int] = None) -> Optional[dict]:
    """Host-side expert-load skew probe for serving telemetry (no profiler).

    Routes ``tokens`` through every MoE router via the embedding probe
    (same approximation as ``core/prefetch.router_probe``), folds the (E,)
    activation counts into per-shard loads, and reports the load imbalance
    (max/mean over shards) plus the modeled per-device a2a volume.
    Returns None when there are no tokens or no MoE layers.

    The math runs entirely in numpy on host copies of the embedding and
    router weights (one explicit ``jax.device_get`` per leaf): eager
    device ops here would inject implicit host transfers into every
    guarded warm stream (``transfer_guard``), and telemetry must never
    perturb what it observes.
    """
    import numpy as np

    toks = np.asarray(tokens).reshape(-1)
    if toks.size == 0 or not any(cfg.moe_pattern):
        return None
    table = np.asarray(jax.device_get(params["embed"]["table"]))
    x = table[toks.astype(np.int64)].astype(np.float32)
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    counts = np.zeros((E,), np.float64)
    for i, is_moe in enumerate(cfg.moe_pattern):
        if not is_moe:
            continue
        router = np.asarray(jax.device_get(
            params["layers"][i]["ffn"]["router"])).astype(np.float32)
        logits = np.einsum("nd,pde->pne", x, router)       # (P, n, E)
        # same top-K set as lax.top_k over softmax probs: softmax is
        # monotone, so the K largest logits are the K activated experts
        topk = np.argpartition(-logits, K - 1, axis=-1)[..., :K]
        np.add.at(counts, topk.reshape(-1), 1.0)
    per_shard = counts.reshape(ep_degree, E // ep_degree).sum(-1)
    mean = float(per_shard.mean())
    if dtype_bytes is None:
        dtype_bytes = 4 if cfg.dtype == "float32" else 2
    return {
        "per_shard_load": per_shard.astype(int).tolist(),
        "imbalance": float(per_shard.max() / mean) if mean else 0.0,
        "a2a_bytes_per_device": ep_a2a_bytes(
            int(toks.size), K, cfg.d_model, ep_degree,
            dtype_bytes=dtype_bytes),
    }

"""Expert-parallel MoE dispatch via shard_map (the §Perf optimization).

The baseline one-hot dispatch (models/moe.py) runs EVERY token through
EVERY expert — E/K-fold redundant compute (usefulness ≈ K/E in the
roofline table) that GSPMD cannot eliminate.  This module replaces it with
explicit expert parallelism:

  * expert weights are sharded over the "model" axis (E/m experts/shard),
  * activations arrive batch-sharded over data and replicated over model,
  * each model shard bins ONLY tokens routed to its local experts
    (capacity bins, paper's balanced-routing assumption), runs the local
    expert FFN, scatters partial outputs, and one psum over "model"
    combines expert contributions.

Per-layer collective cost: one (N, d) all-reduce over the model axis —
instead of E/K-fold FLOPs.  Dense compute per shard: N*K/m tokens worth of
expert FFN (capacity-padded).

Used with Model(..., moe_dispatch="ep"); requires constraints.set_mesh().
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.constraints import get_mesh


def _act(x, activation):
    return jax.nn.gelu(x, approximate=True) if activation == "gelu" else jax.nn.silu(x)


def _local_moe(x, router_w, w_gate, w_up, w_down, *, top_k: int,
               num_experts: int, capacity: int, activation: str,
               model_axis: str):
    """Runs inside shard_map.  x: (N, d) local tokens (replicated over the
    model axis); w_*: (E_local, d, f) this shard's experts."""
    e_local = w_gate.shape[0]
    m_idx = jax.lax.axis_index(model_axis)
    first = m_idx * e_local                               # global id of expert 0

    logits = x.astype(jnp.float32) @ router_w             # (N, E) full router
    probs = jax.nn.softmax(logits, axis=-1)
    weights, indices = jax.lax.top_k(probs, top_k)        # (N, K) global ids
    weights = (weights / jnp.sum(weights, -1, keepdims=True)).astype(x.dtype)

    # keep only (token, k) pairs routed to experts owned by this shard
    local = (indices >= first) & (indices < first + e_local)
    lidx = jnp.where(local, indices - first, e_local)     # e_local = drop bin
    flat_e = lidx.reshape(-1)                             # (N*K,)
    onehot = jax.nn.one_hot(flat_e, e_local + 1, dtype=jnp.int32)
    rank = (jnp.cumsum(onehot, axis=0) - onehot)
    slot = jnp.sum(rank * onehot, -1)
    kept = local.reshape(-1) & (slot < capacity)
    slot = jnp.where(kept, slot, capacity - 1)
    tok = jnp.repeat(jnp.arange(x.shape[0]), top_k)
    bins = jnp.zeros((e_local, capacity, x.shape[1]), x.dtype)
    bins = bins.at[jnp.where(kept, flat_e, 0), slot].add(
        jnp.where(kept[:, None], x[tok], 0))

    h = _act(jnp.einsum("ecd,edf->ecf", bins, w_gate), activation) \
        * jnp.einsum("ecd,edf->ecf", bins, w_up)
    y_bins = jnp.einsum("ecf,efd->ecd", h, w_down)        # (E_local, C, d)

    gathered = y_bins[jnp.where(kept, flat_e, 0), slot]
    gathered = jnp.where(kept[:, None], gathered, 0)
    wk = (weights.reshape(-1) * kept).astype(y_bins.dtype)
    partial_out = jnp.zeros_like(x).at[tok].add(gathered * wk[:, None])
    # combine expert contributions across model shards
    return jax.lax.psum(partial_out, model_axis)


def _local_moe_a2a(x, router_w, w_gate, w_up, w_down, *, top_k: int,
                   num_experts: int, capacity: int, activation: str,
                   model_axis: str, m_shards: int):
    """Two-hop all-to-all EP (DeepSpeed-MoE style), for the FSDP layout
    where tokens are sharded over the model axis too: each tile routes its
    own disjoint tokens, EXCHANGES them with the shards owning the chosen
    experts (all-to-all), computes locally, and exchanges back.  No psum —
    each (token, k) pair is computed exactly once.

    x: (N_loc, d) tokens of this tile; w_*: (e_local, d, f)."""
    N, d = x.shape
    e_local = w_gate.shape[0]
    E = num_experts
    logits = x.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    weights, indices = jax.lax.top_k(probs, top_k)
    weights = (weights / jnp.sum(weights, -1, keepdims=True)).astype(x.dtype)

    flat_e = indices.reshape(-1)                          # (N*K,) global ids
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    slot = jnp.sum((jnp.cumsum(onehot, 0) - onehot) * onehot, -1)
    kept = slot < capacity
    slot = jnp.where(kept, slot, capacity - 1)
    tok = jnp.repeat(jnp.arange(N), top_k)
    send = jnp.zeros((E, capacity, d), x.dtype)
    send = send.at[jnp.where(kept, flat_e, 0), slot].add(
        jnp.where(kept[:, None], x[tok], 0))
    send = send.reshape(m_shards, e_local, capacity, d)

    recv = jax.lax.all_to_all(send, model_axis, split_axis=0, concat_axis=0)
    # recv[j] = tokens from shard j destined to MY experts
    xin = recv.transpose(1, 0, 2, 3).reshape(e_local, m_shards * capacity, d)
    h = _act(jnp.einsum("ecd,edf->ecf", xin, w_gate), activation) \
        * jnp.einsum("ecd,edf->ecf", xin, w_up)
    y = jnp.einsum("ecf,efd->ecd", h, w_down)
    y = y.reshape(e_local, m_shards, capacity, d).transpose(1, 0, 2, 3)
    back = jax.lax.all_to_all(y, model_axis, split_axis=0, concat_axis=0)
    back = back.reshape(E, capacity, d)

    gathered = back[jnp.where(kept, flat_e, 0), slot]
    gathered = jnp.where(kept[:, None], gathered, 0)
    wk = (weights.reshape(-1) * kept).astype(gathered.dtype)
    return jnp.zeros_like(x).at[tok].add(gathered * wk[:, None])


def moe_ep_forward(params: dict, cfg, x: jnp.ndarray, *,
                   capacity_factor: float = 2.0):
    """(B, T, d) → (B, T, d) expert-parallel MoE FFN.  Falls back to the
    dense one-hot path when no mesh is active (single-device tests)."""
    mesh = get_mesh()
    if mesh is None or "model" not in mesh.axis_names \
            or cfg.num_experts % mesh.shape["model"] != 0:
        from repro.models import moe as moe_mod
        return moe_mod.moe_forward(params, cfg, x, dispatch="onehot")[0]

    import math
    from repro.distributed.constraints import get_layout
    B, T, d = x.shape
    layout = get_layout()
    if layout == "fsdp":
        token_axes = tuple(mesh.axis_names)
    else:
        token_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    d_size = math.prod(mesh.shape[a] for a in token_axes) if token_axes else 1
    if (B * T) % max(d_size, 1) != 0:
        token_axes = ()
        d_size = 1
        layout = "tp"
    n_local = B * T // d_size
    # capacity: 128-lane tiles when the workload is large (MXU efficiency),
    # 8-row sublane granularity when tiny — a 128 floor makes EP pad MORE
    # work than one-hot's E/K redundancy at decode scale (§Perf A-iterations)
    want = -(-int(n_local * cfg.num_experts_per_tok * capacity_factor)
             // cfg.num_experts)
    align = 128 if want >= 128 else 8
    capacity = max(align, -(-want // align) * align)

    xf = x.reshape(B * T, d)
    in_specs = (P(token_axes or None, None),              # tokens
                P(),                                      # router (replicated)
                P("model", None, None), P("model", None, None),
                P("model", None, None))
    out_specs = P(token_axes or None, None)
    if layout == "fsdp":
        # tokens sharded over "model" too → two-hop all-to-all EP
        local_fn = partial(_local_moe_a2a, top_k=cfg.num_experts_per_tok,
                           num_experts=cfg.num_experts, capacity=capacity,
                           activation=cfg.mlp_activation, model_axis="model",
                           m_shards=mesh.shape["model"])
    else:
        # tokens replicated over "model" → local-select EP + psum combine
        local_fn = partial(_local_moe, top_k=cfg.num_experts_per_tok,
                           num_experts=cfg.num_experts, capacity=capacity,
                           activation=cfg.mlp_activation, model_axis="model")
    fn = shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False)
    y = fn(xf, params["router"], params["w_gate"], params["w_up"],
           params["w_down"])
    if "shared" in params:
        s = params["shared"]
        y = y + (_act(xf @ s["w_gate"], cfg.mlp_activation)
                 * (xf @ s["w_up"])) @ s["w_down"]
    return y.reshape(B, T, d)

"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B] — dense with MLA attention.

Multi-head Latent Attention: KV compressed to a 256-dim latent (+32-dim
shared rope key); q through a 768-rank LoRA.  Cache stores the latent, not
per-head K/V — the decode_32k KV footprint is ~9x smaller than GQA-40.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
        head_dim=96, d_ff=6400, vocab_size=73448,
        layer_pattern=("mla",),
        mla_kv_lora_rank=256, mla_q_lora_rank=768,
        mla_qk_rope_dim=32, mla_qk_nope_dim=64, mla_v_head_dim=64,
        rope_theta=10_000.0, tie_embeddings=True,
        source="hf:openbmb/MiniCPM3-4B",
    )


def reduced() -> ModelConfig:
    return full().with_overrides(
        name="minicpm3-4b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=4, d_ff=512, vocab_size=512, dtype="float32",
        mla_kv_lora_rank=64, mla_q_lora_rank=48, mla_qk_rope_dim=16,
        mla_qk_nope_dim=32, mla_v_head_dim=32, head_dim=48)


register("minicpm3-4b", full, reduced)

"""Config registry: --arch <id> resolution, reduced smoke variants, drafts."""
from __future__ import annotations

from typing import Callable, Dict

from repro.configs.base import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_REDUCED: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig],
             reduced: Callable[[], ModelConfig]):
    _REGISTRY[name] = full
    _REDUCED[name] = reduced


def get_config(name: str, *, reduced: bool = False, **overrides) -> ModelConfig:
    table = _REDUCED if reduced else _REGISTRY
    if name not in table:
        _load_all()
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(table)}")
    cfg = table[name]()
    return cfg.with_overrides(**overrides) if overrides else cfg


def list_archs() -> list:
    _load_all()
    return sorted(_REGISTRY)


ASSIGNED = (
    "gemma-7b", "minicpm3-4b", "whisper-base", "qwen2-vl-2b", "gemma3-12b",
    "jamba-v0.1-52b", "qwen2-7b", "dbrx-132b", "qwen3-moe-30b-a3b", "xlstm-1.3b",
)


def _load_all():
    from repro.configs import (  # noqa: F401
        gemma_7b, minicpm3_4b, whisper_base, qwen2_vl_2b, gemma3_12b,
        jamba_v01_52b, qwen2_7b, dbrx_132b, qwen3_moe_30b_a3b, xlstm_1_3b,
        qwen2_57b_a14b, mixtral_8x7b, drafts,
    )


def draft_for(cfg: ModelConfig) -> ModelConfig:
    """Default draft model for a target: small dense decoder sharing the
    target's vocab (paper pattern: Qwen2-0.5B for Qwen2-57B-A14B)."""
    return ModelConfig(
        name=f"{cfg.name}-draft",
        family="dense",
        num_layers=4,
        d_model=min(512, cfg.d_model),
        num_heads=8,
        num_kv_heads=2,
        d_ff=4 * min(512, cfg.d_model),
        vocab_size=cfg.vocab_size,
        rope_type="rope" if cfg.rope_type in ("rope", "mrope") else "sinusoidal"
        if cfg.rope_type == "sinusoidal" else "rope",
        dtype=cfg.dtype,
        source="framework default draft",
    )

"""Qwen2-VL-2B [arXiv:2409.12191] — VLM language backbone with M-RoPE.

Vision tower (ViT + merger) is a STUB per the assignment: input_specs()
provides patch embeddings; the LM consumes them via inputs_embeds.  M-RoPE
splits each head's rotary halves into (temporal=16, height=24, width=24)
bands; for pure text all three ids coincide and it reduces to 1-D RoPE.
Dynamic resolution enters through the (t,h,w) position ids, not the LM.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        head_dim=128, d_ff=8960, vocab_size=151936,
        qkv_bias=True, rope_type="mrope", mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0, tie_embeddings=True, frontend="vision_stub",
        source="arXiv:2409.12191",
    )


def reduced() -> ModelConfig:
    return full().with_overrides(
        name="qwen2-vl-2b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        mrope_sections=(8, 12, 12), dtype="float32")


register("qwen2-vl-2b", full, reduced)

"""Gemma-7B [arXiv:2403.08295] — dense, GeGLU, head_dim 256, MHA (kv=16).

The model card's 2B sibling uses MQA; 7B is effectively MHA (16 q / 16 kv).
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
        head_dim=256, d_ff=24576, vocab_size=256000,
        mlp_activation="gelu", rope_theta=10_000.0,
        tie_embeddings=True, norm_type="rmsnorm",
        source="arXiv:2403.08295",
    )


def reduced() -> ModelConfig:
    return full().with_overrides(
        name="gemma-7b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=512, dtype="float32")


register("gemma-7b", full, reduced)

"""DBRX-132B [hf:databricks/dbrx-base] — fine-grained MoE, 16 experts top-4.

rho = 4/16 = 0.25; T_thres(tau=.95) = 11 tokens — expert activation
saturates at tiny batches, the classic MoESD regime."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=10752, vocab_size=100352,
        num_experts=16, num_experts_per_tok=4, moe_d_ff=10752,
        rope_theta=500_000.0, norm_type="layernorm",
        source="hf:databricks/dbrx-base",
    )


def reduced() -> ModelConfig:
    return full().with_overrides(
        name="dbrx-132b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        num_experts=4, num_experts_per_tok=2, moe_d_ff=512, dtype="float32")


register("dbrx-132b", full, reduced)

"""Gemma3-12B [hf:google/gemma-3-1b-pt family] — dense, 5:1 local:global.

Five sliding-window (1024) layers per one global layer; 128k context
native.  The interleave makes long_500k decode feasible faithfully: only
8/48 layers keep a full-length KV.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense",
        num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
        head_dim=256, d_ff=15360, vocab_size=262144,
        layer_pattern=("swa", "swa", "swa", "swa", "swa", "attn"),
        sliding_window=1024, mlp_activation="gelu",
        rope_theta=1_000_000.0, tie_embeddings=True,
        final_logit_softcap=30.0,
        source="hf:google/gemma-3-1b-pt (scaled per card family)",
    )


def reduced() -> ModelConfig:
    return full().with_overrides(
        name="gemma3-12b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        layer_pattern=("swa", "attn"), moe_pattern=(False, False),
        sliding_window=16, dtype="float32")


register("gemma3-12b", full, reduced)

"""Whisper-base [arXiv:2212.04356] — audio encoder-decoder backbone.

Per the assignment carve-out the mel-spectrogram + conv frontend is a STUB:
input_specs() feeds precomputed frame embeddings (B, 1500, 512) directly to
the encoder.  Deviations (DESIGN.md §9): decoder positions are sinusoidal
(not learned) so the assigned 32k/500k decode shapes exceed the original
448-token table; long_500k additionally uses the SWA-4096 variant on
decoder self-attention (cross-attention is O(1) in S — fixed 1500 frames).
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
        head_dim=64, d_ff=2048, vocab_size=51865,
        is_encoder_decoder=True, encoder_layers=6, encoder_seq_len=1500,
        rope_type="sinusoidal", norm_type="layernorm", frontend="audio_stub",
        tie_embeddings=True,
        source="arXiv:2212.04356",
    )


def reduced() -> ModelConfig:
    return full().with_overrides(
        name="whisper-base-reduced", num_layers=2, encoder_layers=2,
        d_model=128, num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256,
        vocab_size=512, encoder_seq_len=16, dtype="float32")


register("whisper-base", full, reduced)

"""Draft-model configs (paper Sec. 4: standalone small same-family models)."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


def qwen2_0_5b() -> ModelConfig:
    """Qwen2-0.5B-Instruct — the paper's draft for Qwen2-57B-A14B."""
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
        head_dim=64, d_ff=4864, vocab_size=151936,
        qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
        source="arXiv:2407.10671",
    )


def qwen2_0_5b_reduced() -> ModelConfig:
    return qwen2_0_5b().with_overrides(
        name="qwen2-0.5b-reduced", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512, dtype="float32")


register("qwen2-0.5b", qwen2_0_5b, qwen2_0_5b_reduced)

"""Mixtral-8x7B [arXiv:2401.04088] — the paper's second target (Eagle-head
draft in the paper; we pair it with a small dense draft)."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=32000,
        num_experts=8, num_experts_per_tok=2, moe_d_ff=14336,
        rope_theta=1_000_000.0,
        source="arXiv:2401.04088",
    )


def reduced() -> ModelConfig:
    return full().with_overrides(
        name="mixtral-8x7b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        num_experts=4, num_experts_per_tok=2, moe_d_ff=512, dtype="float32")


register("mixtral-8x7b", full, reduced)

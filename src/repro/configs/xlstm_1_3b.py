"""xLSTM-1.3B [arXiv:2405.04517] — recurrent sLSTM + mLSTM stack (7:1).

No attention, no KV cache: decode state is O(1) in sequence length, so
long_500k runs natively.  The MoESD *analysis* is inapplicable (no MoE
FFN, d_ff=0 — mLSTM blocks are self-contained); the SD *engine* still
serves it via per-step state collection + commit-gather (DESIGN.md §4)."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

_PATTERN = ("mlstm",) * 7 + ("slstm",)


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304, head_dim=512,
        layer_pattern=_PATTERN, rope_type="none", norm_type="layernorm",
        source="arXiv:2405.04517",
    )


def reduced() -> ModelConfig:
    return full().with_overrides(
        name="xlstm-1.3b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=4, head_dim=64, vocab_size=512,
        layer_pattern=("mlstm", "slstm"), moe_pattern=(False, False),
        dtype="float32")


register("xlstm-1.3b", full, reduced)

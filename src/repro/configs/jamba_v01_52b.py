"""Jamba-v0.1-52B [arXiv:2403.19887] — hybrid Mamba+attention MoE.

Period of 8 layers: 1 attention : 7 Mamba (attention at index 4 per the
paper's block diagram); MoE FFN every other layer (e=2), 16 experts top-2.
The MoESD analysis applies to the MoE layers; the Mamba layers carry
recurrent state through the SD verify/commit path (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

_PATTERN = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")
_MOE = (False, True, False, True, False, True, False, True)


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=65536,
        layer_pattern=_PATTERN, moe_pattern=_MOE,
        num_experts=16, num_experts_per_tok=2, moe_d_ff=14336,
        ssm_state_dim=16, ssm_conv_dim=4, ssm_expand=2,
        rope_type="none",          # Jamba uses no positional encoding
        source="arXiv:2403.19887",
    )


def reduced() -> ModelConfig:
    return full().with_overrides(
        name="jamba-v0.1-52b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        layer_pattern=("mamba", "attn"), moe_pattern=(True, False),
        num_experts=4, num_experts_per_tok=2, moe_d_ff=512, dtype="float32")


register("jamba-v0.1-52b", full, reduced)

"""Qwen2-57B-A14B [arXiv:2407.10671] — the PAPER's headline target model.

64 experts top-8 (rho=0.125) + one 8x shared expert; every speedup table
(Tables 1-2) and the sparsity sweep (Fig. 4, K in {1,2,4,8,16,32} via
num_experts_per_tok override) run on this config."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-57b-a14b", family="moe",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        head_dim=128, d_ff=2560, vocab_size=151936,
        num_experts=64, num_experts_per_tok=8, moe_d_ff=2560,
        num_shared_experts=8, qkv_bias=True, rope_theta=1_000_000.0,
        source="arXiv:2407.10671 (Qwen2 technical report)",
    )


def reduced() -> ModelConfig:
    return full().with_overrides(
        name="qwen2-57b-a14b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=128, vocab_size=512,
        num_experts=4, num_experts_per_tok=2, moe_d_ff=128,
        num_shared_experts=1, dtype="float32")


register("qwen2-57b-a14b", full, reduced)

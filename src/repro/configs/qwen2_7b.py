"""Qwen2-7B [arXiv:2407.10671] — dense GQA with QKV bias.  Also serves as
the paper's dense control family (its 0.5B sibling is the paper's draft)."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b", family="dense",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        head_dim=128, d_ff=18944, vocab_size=152064,
        qkv_bias=True, rope_theta=1_000_000.0,
        source="arXiv:2407.10671",
    )


def reduced() -> ModelConfig:
    return full().with_overrides(
        name="qwen2-7b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512, dtype="float32")


register("qwen2-7b", full, reduced)

"""Model / run configuration system.

A single flat dataclass covers every assigned architecture family
(dense / MoE / SSM / hybrid / audio enc-dec / VLM).  Heterogeneous layer
stacks are expressed with ``layer_pattern`` — one *period* of block kinds
that is tiled ``num_layers / len(layer_pattern)`` times, which is also the
unit the transformer scans over (keeps HLO small for 62-layer models).

Block kinds:
  "attn"    full causal self-attention (GQA/MQA per num_kv_heads)
  "swa"     sliding-window self-attention (window = sliding_window)
  "mla"     multi-head latent attention (DeepSeek-V2 style, MiniCPM3)
  "mamba"   Mamba selective-SSM block (Jamba)
  "mlstm"   xLSTM matrix-LSTM block
  "slstm"   xLSTM scalar-LSTM block

``moe_pattern`` parallels ``layer_pattern``: True → the FFN of that layer is
a routed MoE, False → dense FFN.  Empty pattern → all-dense (or all-MoE if
num_experts > 0).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // num_heads

    # ---- layer stack ------------------------------------------------------
    layer_pattern: Tuple[str, ...] = ()      # one period; () → all "attn"
    moe_pattern: Tuple[bool, ...] = ()       # parallels layer_pattern

    # ---- FFN --------------------------------------------------------------
    mlp_activation: str = "silu"             # "silu" (SwiGLU) | "gelu" (GeGLU)

    # ---- attention --------------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_type: str = "rope"                  # "rope" | "mrope" | "learned" | "sinusoidal"
    mrope_sections: Tuple[int, ...] = ()     # qwen2-vl: rotary dims per (t,h,w)
    sliding_window: int = 0                  # used by "swa" blocks
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    max_position_embeddings: int = 1_048_576

    # ---- MLA (MiniCPM3 / DeepSeek-V2) --------------------------------------
    mla_kv_lora_rank: int = 0
    mla_q_lora_rank: int = 0
    mla_qk_rope_dim: int = 0
    mla_qk_nope_dim: int = 0
    mla_v_head_dim: int = 0

    # ---- MoE ---------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0                        # expert hidden dim; 0 → d_ff
    num_shared_experts: int = 0              # always-on shared experts
    router_aux_loss_coef: float = 0.0
    router_jitter: float = 0.0

    # ---- SSM (Mamba) -------------------------------------------------------
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0                     # 0 → ceil(d_model / 16)

    # ---- encoder-decoder (whisper) -----------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1500              # whisper: 1500 frames after conv

    # ---- modality frontend stub --------------------------------------------
    frontend: str = "none"                   # none | audio_stub | vision_stub

    # ---- misc ---------------------------------------------------------------
    norm_type: str = "rmsnorm"               # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"                  # activation/param dtype
    source: str = ""                         # citation for the config

    # ------------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.layer_pattern:
            object.__setattr__(self, "layer_pattern", ("attn",))
        if not self.moe_pattern:
            default_moe = self.num_experts > 0
            object.__setattr__(
                self, "moe_pattern", tuple(default_moe for _ in self.layer_pattern)
            )
        if len(self.moe_pattern) != len(self.layer_pattern):
            raise ValueError(
                f"{self.name}: moe_pattern length {len(self.moe_pattern)} != "
                f"layer_pattern length {len(self.layer_pattern)}"
            )
        if self.num_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern period {len(self.layer_pattern)}"
            )
        if self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # ---- derived quantities -------------------------------------------------
    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def moe_sparsity(self) -> float:
        """rho = K / E (paper §3.2)."""
        if self.num_experts == 0:
            return 1.0
        return self.num_experts_per_tok / self.num_experts

    @property
    def is_recurrent(self) -> bool:
        """True if any block keeps recurrent (non-KV) state."""
        return any(k in ("mamba", "mlstm", "slstm") for k in self.layer_pattern)

    @property
    def has_full_attention(self) -> bool:
        return any(k in ("attn", "mla") for k in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Can this config decode at 500k context without O(S) full-attn KV on
        every layer?  (Some full-attn layers are OK if a minority — gemma3 /
        jamba keep a few global layers.)"""
        if not self.has_full_attention:
            return True
        n_full = sum(1 for k in self.layer_pattern if k in ("attn", "mla"))
        return n_full / self.period <= 0.5

    def param_count(self) -> int:
        """Total parameter count (embedding + stack + head), exact."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only K experts)."""
        return _param_count(self, active_only=True)

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    # gated MLP: gate + up + down
    return 3 * cfg.d_model * d_ff


def _attn_params(cfg: ModelConfig, kind: str) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    if kind == "mla":
        r_kv, r_q = cfg.mla_kv_lora_rank, cfg.mla_q_lora_rank
        qk = cfg.mla_qk_rope_dim + cfg.mla_qk_nope_dim
        n = 0
        n += d * (r_kv + cfg.mla_qk_rope_dim)                # kv down (+ rope k)
        n += r_kv * cfg.num_heads * (cfg.mla_qk_nope_dim + cfg.mla_v_head_dim)
        if r_q:
            n += d * r_q + r_q * cfg.num_heads * qk
        else:
            n += d * cfg.num_heads * qk
        n += cfg.num_heads * cfg.mla_v_head_dim * d          # out proj
        return n
    # gqa / swa
    n = d * cfg.num_heads * hd                               # q
    n += 2 * d * cfg.num_kv_heads * hd                       # k, v
    n += cfg.num_heads * hd * d                              # o
    if cfg.qkv_bias:
        n += (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
    return n


def _ssm_params(cfg: ModelConfig, kind: str) -> int:
    d = cfg.d_model
    if kind == "mamba":
        d_in = cfg.ssm_expand * d
        dt_rank = cfg.ssm_dt_rank or -(-d // 16)
        n = d * 2 * d_in                                     # in proj (x, z)
        n += d_in * cfg.ssm_conv_dim                         # conv
        n += d_in * (dt_rank + 2 * cfg.ssm_state_dim)        # x -> dt,B,C
        n += dt_rank * d_in                                  # dt proj
        n += d_in * cfg.ssm_state_dim + d_in                 # A_log, D
        n += d_in * d                                        # out proj
        return n
    if kind == "mlstm":
        d_in = 2 * d
        hd = d_in // cfg.num_heads
        n = d * 2 * d_in                                     # up proj (x, z)
        n += 3 * d_in * d_in                                 # q,k,v
        n += 2 * cfg.num_heads * d_in                        # i,f gates (per head)
        n += d_in * d                                        # down proj
        return n
    if kind == "slstm":
        n = 4 * d * d + 4 * d * d                            # input + recurrent (4 gates)
        n += 2 * (d * (4 * d) // 3)                          # up/down ffn (4/3 ratio)
        return n
    raise ValueError(kind)


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    n = cfg.vocab_size * cfg.d_model                         # embed
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * cfg.d_model                    # lm head
    per_period = 0
    for kind, is_moe in zip(cfg.layer_pattern, cfg.moe_pattern):
        if kind in ("attn", "swa", "mla"):
            per_period += _attn_params(cfg, kind)
        else:
            per_period += _ssm_params(cfg, kind)
        if is_moe:
            e = cfg.num_experts_per_tok if active_only else cfg.num_experts
            per_period += e * _ffn_params(cfg, cfg.moe_d_ff)
            per_period += cfg.num_shared_experts * _ffn_params(cfg, cfg.moe_d_ff)
            per_period += cfg.d_model * cfg.num_experts      # router
        elif kind not in ("mamba", "mlstm", "slstm"):
            per_period += _ffn_params(cfg, cfg.d_ff)
        per_period += 2 * cfg.d_model                        # 2 norms / layer
    n += per_period * cfg.num_periods
    if cfg.is_encoder_decoder:
        # encoder layers: bidirectional attn + ffn + cross-attn params on decoder
        enc = cfg.encoder_layers * (
            _attn_params(cfg, "attn") + _ffn_params(cfg, cfg.d_ff) + 2 * cfg.d_model
        )
        cross = cfg.num_layers * (_attn_params(cfg, "attn") + cfg.d_model)
        n += enc + cross
    return n


# ---------------------------------------------------------------------------
# Run / shape configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                                # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class SpecDecodeConfig:
    """Speculative-decoding runtime config (the paper's knobs)."""
    gamma: int = 4                            # draft length per round
    temperature: float = 0.0
    max_new_tokens: int = 64
    greedy_draft: bool = True
    tau: float = 0.95                         # activation-saturation threshold (Eq. 9)


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1_000
    remat: bool = True
    seed: int = 0

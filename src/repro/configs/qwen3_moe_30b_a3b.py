"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — 128 experts top-8.

rho = 8/128 = 0.0625 — the sparsest assigned architecture and the paper's
sweet spot: T_thres(tau=.95) = 47 tokens, so the SD-favourable moderate-
batch window is the widest here (benchmarks/sparsity_sweep.py)."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
        head_dim=128, d_ff=768, vocab_size=151936,
        num_experts=128, num_experts_per_tok=8, moe_d_ff=768,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen3-30B-A3B",
    )


def reduced() -> ModelConfig:
    return full().with_overrides(
        name="qwen3-moe-30b-a3b-reduced", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=2, head_dim=64, d_ff=128, vocab_size=512,
        num_experts=4, num_experts_per_tok=2, moe_d_ff=128, dtype="float32")


register("qwen3-moe-30b-a3b", full, reduced)
